//! Integration tests for the perf-trajectory artifacts: fixed-iteration
//! bench runs must produce byte-identical `deterministic` sections, the
//! artifact file round-trips through `$SKYMEMORY_BENCH_DIR`, and
//! `sim::diff::diff_bench_metrics` (the `skymemory bench --diff` core)
//! gates counter drift and timing regressions the way docs/METRICS.md
//! promises.

use skymemory::kvc::hash::sha256;
use skymemory::sim::diff::diff_bench_metrics;
use skymemory::util::bench::{summarize, BenchArtifact, Bencher};
use skymemory::util::json::Json;
use std::time::Duration;

/// One miniature "--smoke bench run": fixed iteration counts, seeded
/// workload, a couple of hand-rolled counters — the same shape every
/// bench binary produces.
fn smoke_run() -> String {
    let mut art = BenchArtifact::new("golden", true);
    let payload = vec![0x5Au8; 4096];
    let r = Bencher::new("sha256 4 KiB")
        .fixed_iters(32)
        .batch(4)
        .bytes_per_iter(payload.len())
        .run(|| {
            std::hint::black_box(sha256(&payload));
        });
    art.push(&r);
    let r = Bencher::new("noop").fixed_iters(16).run(|| {
        std::hint::black_box(1 + 1);
    });
    art.push(&r);
    art.counter("sched.transfers", 96);
    art.label("host", "test");
    art.timing_ns("wall_ns", 1); // timing differs run-over-run; this doesn't matter
    art.to_json_string()
}

fn deterministic_section(artifact: &str) -> String {
    Json::parse(artifact).unwrap().get("deterministic").unwrap().to_string()
}

#[test]
fn two_smoke_runs_have_byte_identical_deterministic_sections() {
    let one = smoke_run();
    let two = smoke_run();
    assert_eq!(deterministic_section(&one), deterministic_section(&two));
    // and the timing namespace exists with the promised stats
    let timing = Json::parse(&one).unwrap();
    let timing = timing.get("timing").unwrap();
    let stats = timing.get("sha256_4_kib").unwrap();
    for key in ["max_ns", "mean_ns", "min_ns", "p50_ns", "p95_ns", "p99_ns"] {
        assert!(stats.get(key).unwrap().as_f64().is_some(), "{key}");
    }
    // the deterministic counters are the statically-known ones
    let det = Json::parse(&one).unwrap();
    let det = det.get("deterministic").unwrap();
    assert_eq!(det.get("sha256_4_kib").unwrap().get("iters").unwrap().as_u64(), Some(32));
    assert_eq!(det.get("sha256_4_kib").unwrap().get("bytes").unwrap().as_u64(), Some(32 * 4096));
    assert_eq!(det.get("noop").unwrap().get("iters").unwrap().as_u64(), Some(16));
    assert_eq!(det.get("sched.transfers").unwrap().as_u64(), Some(96));
}

#[test]
fn identical_smoke_runs_diff_clean_det_only() {
    // det-only is what CI runs: wall-clock numbers from two runs (or two
    // machines) are never comparable, the counters always are
    let report = diff_bench_metrics(&smoke_run(), &smoke_run(), 0.15, true).unwrap();
    assert!(!report.has_regressions(), "{}", report.render());
}

#[test]
fn counter_drift_is_a_regression_in_both_directions() {
    let base = smoke_run();
    let drifted = base.replace(r#""sched.transfers":96"#, r#""sched.transfers":95"#);
    assert_ne!(base, drifted);
    let report = diff_bench_metrics(&base, &drifted, 0.15, true).unwrap();
    assert!(report.has_regressions(), "{}", report.render());
    let report = diff_bench_metrics(&drifted, &base, 0.15, true).unwrap();
    assert!(report.has_regressions(), "counter rising must also regress");
}

#[test]
fn timing_gate_is_direction_aware_with_tolerance() {
    let mut a = BenchArtifact::new("t", true);
    a.timing_ns("op.mean_ns", 1000);
    let mk = |ns: u64| {
        let mut b = BenchArtifact::new("t", true);
        b.timing_ns("op.mean_ns", ns);
        b.to_json_string()
    };
    let a = a.to_json_string();
    // +10% is inside the default ±15% tolerance; +30% is not; -50% is an
    // improvement and never regresses
    assert!(!diff_bench_metrics(&a, &mk(1100), 0.15, false).unwrap().has_regressions());
    assert!(diff_bench_metrics(&a, &mk(1300), 0.15, false).unwrap().has_regressions());
    assert!(!diff_bench_metrics(&a, &mk(500), 0.15, false).unwrap().has_regressions());
    // det-only ignores even a 9x timing blowup
    assert!(!diff_bench_metrics(&a, &mk(9000), 0.15, true).unwrap().has_regressions());
}

#[test]
fn bootstrap_baselines_tolerate_added_counters_but_not_drops() {
    // the committed baselines carry a subset of the counters (the
    // statically-computable ones); fresh runs adding keys is fine,
    // dropping a tracked counter is a regression
    let full = smoke_run();
    let subset = {
        let mut art = BenchArtifact::new("golden", true);
        let r = summarize("noop", vec![Duration::from_nanos(10); 16]);
        art.push(&r);
        art.counter("sched.transfers", 96);
        art.to_json_string()
    };
    let report = diff_bench_metrics(&subset, &full, 0.15, true).unwrap();
    assert!(!report.has_regressions(), "added counters are neutral: {}", report.render());
    let report = diff_bench_metrics(&full, &subset, 0.15, true).unwrap();
    assert!(report.has_regressions(), "dropped counters must regress");
}

#[test]
fn artifact_write_honors_bench_dir() {
    let dir = std::env::temp_dir().join(format!("skymem_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::env::set_var("SKYMEMORY_BENCH_DIR", &dir);
    let mut art = BenchArtifact::new("envtest", true);
    art.counter("k", 1);
    let path = art.write().unwrap();
    std::env::remove_var("SKYMEMORY_BENCH_DIR");
    assert_eq!(path, dir.join("BENCH_envtest.json"));
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(text.ends_with('\n'));
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed.get("name").unwrap().as_str(), Some("envtest"));
    assert_eq!(parsed.get("mode").unwrap().as_str(), Some("smoke"));
    std::fs::remove_dir_all(&dir).ok();
}
