//! Integration: the UDP constellation — real sockets, SPP framing, greedy
//! multi-hop forwarding, migration over the mesh, and the KVC manager
//! running the full protocol over UdpTransport (the paper's NUC testbed
//! shape, §5).

use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::kvc::block::block_hashes;
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::kvc::manager::{KvcConfig, KvcManager};
use skymemory::net::transport::{GroundView, Transport};
use skymemory::net::udp::{UdpFleet, UdpTransport};
use skymemory::util::rng::XorShift64;
use std::sync::Arc;
use std::time::Duration;

fn values(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShift64::new(seed);
    (0..n).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect()
}

fn udp_manager(torus: Torus, center: SatId) -> (UdpFleet, KvcManager) {
    let fleet = UdpFleet::spawn(torus, 10 << 20, EvictionPolicy::Gossip, None).unwrap();
    let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
    let transport: Arc<dyn Transport> = Arc::new(
        UdpTransport::new(torus, fleet.book.clone(), ground, Duration::from_secs(5)).unwrap(),
    );
    let cfg = KvcConfig { n_servers: 10, chunk_size: 600, ..KvcConfig::default() };
    let manager = KvcManager::new(cfg, torus, transport);
    (fleet, manager)
}

#[test]
fn full_protocol_over_udp_19x5() {
    // the paper's 19x5 constellation, 10 servers
    let torus = Torus::new(5, 19);
    let (fleet, m) = udp_manager(torus, SatId::new(2, 9));
    let tokens: Vec<i32> = (0..128).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..4 {
        assert!(m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap());
    }
    let (blocks, _) = m.lookup(&hashes, 0).unwrap();
    assert_eq!(blocks, 4);
    let fetch = m.fetch_prefix(&hashes, blocks, 0).unwrap();
    assert_eq!(fetch.blocks, 4);
    for (i, kv) in fetch.kv_blocks.iter().enumerate() {
        let orig = values(2048, i as u64);
        let max_err =
            orig.iter().zip(kv).map(|(a, b)| (a - b).abs()).fold(0f32, f32::max);
        assert!(max_err < 0.06, "block {i}: {max_err}");
    }
    assert!(fleet.total_chunks() > 0);
    fleet.shutdown();
}

#[test]
fn udp_migration_epoch_preserves_cache() {
    let torus = Torus::new(5, 19);
    let (fleet, m) = udp_manager(torus, SatId::new(2, 9));
    let tokens: Vec<i32> = (0..64).collect();
    let hashes = block_hashes(&tokens, 32);
    for b in 0..2 {
        m.put_block(&hashes, b, &values(2048, b as u64), 0).unwrap();
    }
    let stored = fleet.total_chunks();
    m.advance_epoch(0).unwrap();
    // migration Sets ride the mesh asynchronously; wait for convergence
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        if fleet.total_chunks() == stored {
            if let Ok(f) = m.fetch_prefix(&hashes, 2, 1) {
                if f.blocks == 2 {
                    break;
                }
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "cache did not converge after migration ({} of {stored} chunks)",
            fleet.total_chunks()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    fleet.shutdown();
}

#[test]
fn partial_plane_hosting_routes_around() {
    // host only planes 0..3 of a 3-plane torus in this "process" — the
    // paper's per-NUC partitioning, all planes present here but spawned
    // through the partition API
    let torus = Torus::new(3, 7);
    let f0 = UdpFleet::spawn(torus, 1 << 20, EvictionPolicy::Gossip, Some(0..3)).unwrap();
    assert_eq!(f0.book.len(), 21);
    let center = SatId::new(1, 3);
    let ground = GroundView::new(center, &LosGrid::new(center, 1, 1), torus.sats_per_plane);
    let t =
        UdpTransport::new(torus, f0.book.clone(), ground, Duration::from_secs(2)).unwrap();
    // far corner requires multi-hop forwarding through both axes
    let far = SatId::new(0, 0);
    t.set_chunk(far, skymemory::kvc::chunk::ChunkKey::new(
        skymemory::kvc::block::BlockHash([9; 32]), 0), vec![1, 2, 3]).unwrap();
    assert_eq!(
        t.get_chunk(far, skymemory::kvc::chunk::ChunkKey::new(
            skymemory::kvc::block::BlockHash([9; 32]), 0)).unwrap(),
        Some(vec![1, 2, 3])
    );
    f0.shutdown();
}

#[test]
fn udp_timeout_on_dead_satellite_is_an_error_not_a_hang() {
    let torus = Torus::new(3, 5);
    // spawn only plane 0; destinations in plane 2 are reachable by routing
    // THROUGH plane 1... which does not exist -> the request dies and the
    // client times out cleanly
    let fleet = UdpFleet::spawn(torus, 1 << 20, EvictionPolicy::Gossip, Some(0..1)).unwrap();
    let center = SatId::new(0, 2);
    let ground = GroundView::new(center, &LosGrid::new(center, 1, 0), torus.sats_per_plane);
    let t = UdpTransport::new(
        torus,
        fleet.book.clone(),
        ground,
        Duration::from_millis(300),
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let r = t.ping(SatId::new(2, 2));
    assert!(r.is_err());
    assert!(t0.elapsed() < Duration::from_secs(2));
    fleet.shutdown();
}
