//! Property-based tests over the coordinator invariants (routing,
//! batching, state).  The offline build has no proptest crate, so this is
//! a from-scratch property harness: deterministic XorShift-driven random
//! cases with failure seeds printed for reproduction.

use skymemory::constellation::geometry::Geometry;
use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::kvc::block::{block_hashes, BlockHash};
use skymemory::kvc::chunk::{chunk_count, join_chunks, split_chunks, ChunkKey};
use skymemory::kvc::eviction::{EvictionPolicy, LruTracker};
use skymemory::kvc::quantize::Quantizer;
use skymemory::kvc::radix::{BlockIndex, BlockMeta, RadixTree};
use skymemory::mapping::{box_width, Strategy};
use skymemory::net::messages::{
    decode_request, decode_response, encode_request, encode_response, Envelope, Request, Response,
};
use skymemory::net::sched::{ChunkOp, ChunkResult, NetScheduler, SchedConfig, Transfer};
use skymemory::net::transport::{GroundView, InProcTransport, LinkModel, Transport};
use skymemory::obs::mem::MemFootprint;
use skymemory::satellite::fleet::Fleet;
use skymemory::satellite::store::ChunkStore;
use skymemory::util::rng::XorShift64;
use std::sync::Arc;

const CASES: u64 = 300;

fn rand_torus(rng: &mut XorShift64) -> Torus {
    Torus::new(2 + rng.next_range(14), 2 + rng.next_range(20))
}

fn rand_sat(rng: &mut XorShift64, t: &Torus) -> SatId {
    SatId::new(rng.next_range(t.planes) as u16, rng.next_range(t.sats_per_plane) as u16)
}

#[test]
fn prop_greedy_route_always_realizes_min_hops() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 1);
        let t = rand_torus(&mut rng);
        let a = rand_sat(&mut rng, &t);
        let b = rand_sat(&mut rng, &t);
        let route = t.route(a, b);
        assert_eq!(route.len(), t.hops(a, b), "seed {seed}: {a} -> {b}");
        let mut prev = a;
        for s in route {
            assert!(t.neighbors(prev).contains(&s), "seed {seed}: non-neighbor step");
            prev = s;
        }
        assert_eq!(prev, b, "seed {seed}");
    }
}

#[test]
fn prop_layouts_unique_cover_and_start_at_center() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 10_000);
        let t = rand_torus(&mut rng);
        let c = rand_sat(&mut rng, &t);
        let max_n = t.len().min(box_width(t.len()) * box_width(t.len()));
        let n = 1 + rng.next_range(max_n.min(81));
        for st in Strategy::ALL {
            // bounded strategies need the box to fit inside the torus
            let w = box_width(n);
            if st != Strategy::HopAware && (w > t.planes || w > t.sats_per_plane) {
                continue;
            }
            let layout = st.initial_layout(&t, c, n);
            assert_eq!(layout.len(), n, "seed {seed} {:?}", st);
            assert_eq!(layout[0], c, "seed {seed} {:?}: server 1 must be closest", st);
            let uniq: std::collections::HashSet<_> = layout.iter().collect();
            assert_eq!(uniq.len(), n, "seed {seed} {:?}: duplicates", st);
        }
    }
}

#[test]
fn prop_migration_closed_form_equals_chained_plans() {
    for seed in 0..150 {
        let mut rng = XorShift64::new(seed + 20_000);
        let t = Torus::new(3 + rng.next_range(10), 7 + rng.next_range(14));
        let c = rand_sat(&mut rng, &t);
        let n = 1 + rng.next_range(25);
        let w = box_width(n);
        if w + 1 >= t.sats_per_plane || w > t.planes {
            continue;
        }
        let st = if rng.next_range(2) == 0 {
            Strategy::RotationAware
        } else {
            Strategy::RotationHopAware
        };
        let mut layout = st.layout_at(&t, c, n, 0);
        for epoch in 0..6u64 {
            let plan = skymemory::mapping::migration::migration_plan(&t, st, c, n, epoch);
            for m in &plan {
                layout[(m.server - 1) as usize] = m.to;
            }
            assert_eq!(
                layout,
                st.layout_at(&t, c, n, epoch + 1),
                "seed {seed} {:?} epoch {epoch}",
                st
            );
        }
    }
}

#[test]
fn prop_route_length_matches_hops_symmetric_under_wraparound() {
    // torus routing: the greedy route always realizes exactly `hops`
    // steps, hop distance is symmetric, and both are invariant under
    // wrap-around translation of the endpoints (full-axis translations
    // are the identity).
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 100_000);
        let t = rand_torus(&mut rng);
        let a = rand_sat(&mut rng, &t);
        let b = rand_sat(&mut rng, &t);
        assert_eq!(t.route(a, b).len(), t.hops(a, b), "seed {seed}: {a} -> {b}");
        assert_eq!(t.hops(a, b), t.hops(b, a), "seed {seed}: symmetry");
        assert_eq!(t.route(b, a).len(), t.route(a, b).len(), "seed {seed}");
        // a full-axis translation wraps to the identity
        assert_eq!(t.offset(a, t.planes as i32, t.sats_per_plane as i32), a, "seed {seed}");
        // arbitrary translations (including wrapping ones) preserve the
        // metric and the realized route length
        let dp = rng.next_range(2 * t.planes) as i32 - t.planes as i32;
        let ds = rng.next_range(2 * t.sats_per_plane) as i32 - t.sats_per_plane as i32;
        let (ta, tb) = (t.offset(a, dp, ds), t.offset(b, dp, ds));
        assert_eq!(t.hops(ta, tb), t.hops(a, b), "seed {seed}: translation invariance");
        assert_eq!(t.route(ta, tb).len(), t.route(a, b).len(), "seed {seed}");
    }
}

#[test]
fn prop_chained_hash_prefix_stability_across_quantizers() {
    // two prompts sharing a block-aligned token prefix share exactly that
    // prefix of chained hashes; and for every quantizer variant, a shared
    // value prefix (group-aligned) yields an identical encoded prefix, so
    // the stored chunk stream of a shared prefix is identical no matter
    // which codec the deployment picked.
    for seed in 0..100 {
        let mut rng = XorShift64::new(seed + 110_000);
        let bs = 1 + rng.next_range(12);
        let shared_blocks = 1 + rng.next_range(6);
        let tail_blocks = 1 + rng.next_range(4);
        let shared: Vec<i32> =
            (0..shared_blocks * bs).map(|_| rng.next_range(1 << 16) as i32).collect();
        let mut a = shared.clone();
        let mut b = shared.clone();
        a.extend((0..tail_blocks * bs).map(|_| rng.next_range(1 << 16) as i32));
        b.extend((0..tail_blocks * bs).map(|_| rng.next_range(1 << 16) as i32));
        b[shared.len()] = a[shared.len()].wrapping_add(1); // tails diverge at once
        let ha = block_hashes(&a, bs);
        let hb = block_hashes(&b, bs);
        assert_eq!(
            &ha[..shared_blocks],
            &hb[..shared_blocks],
            "seed {seed}: shared token prefix must share hash prefix"
        );
        for i in shared_blocks..ha.len() {
            assert_ne!(ha[i], hb[i], "seed {seed} block {i}: diverged chains must differ");
        }

        let group = 32usize;
        let n_groups = 2 + rng.next_range(6);
        let vals: Vec<f32> =
            (0..group * n_groups).map(|_| (rng.next_f64() as f32 - 0.5) * 3.0).collect();
        let prefix_groups = 1 + rng.next_range(n_groups);
        for q in [
            Quantizer::F32,
            Quantizer::QuantoInt8 { group },
            Quantizer::HqqInt8 { group },
        ] {
            let bytes_per_group = match q {
                Quantizer::F32 => 4 * group,
                Quantizer::QuantoInt8 { .. } => 4 + group,
                Quantizer::HqqInt8 { .. } => 8 + group,
            };
            let full = q.encode(&vals);
            assert_eq!(full, q.encode(&vals), "seed {seed} {}: deterministic", q.name());
            let prefix = q.encode(&vals[..prefix_groups * group]);
            assert_eq!(prefix.len(), prefix_groups * bytes_per_group, "seed {seed}");
            assert_eq!(
                &full[..prefix.len()],
                &prefix[..],
                "seed {seed} {}: shared values must share encoded prefix",
                q.name()
            );
        }
    }
}

#[test]
fn prop_block_hash_prefix_property() {
    // two token streams agree on their chained hashes exactly as far as
    // their common block-aligned prefix
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 30_000);
        let bs = 1 + rng.next_range(16);
        let len = bs * (1 + rng.next_range(8));
        let mut a: Vec<i32> = (0..len).map(|_| rng.next_range(1000) as i32).collect();
        let mut b = a.clone();
        let flip = rng.next_range(len);
        b[flip] = a[flip].wrapping_add(1);
        let ha = block_hashes(&a, bs);
        let hb = block_hashes(&b, bs);
        let flip_block = flip / bs;
        for i in 0..ha.len() {
            if i < flip_block {
                assert_eq!(ha[i], hb[i], "seed {seed} block {i}");
            } else {
                assert_ne!(ha[i], hb[i], "seed {seed} block {i}");
            }
        }
        // restoring the token restores all hashes
        a[flip] = b[flip];
        assert_eq!(block_hashes(&a, bs), hb);
    }
}

#[test]
fn prop_chunk_split_join_roundtrip() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 40_000);
        let len = rng.next_range(40_000);
        let chunk = 1 + rng.next_range(8192);
        let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        let chunks = split_chunks(&data, chunk);
        assert_eq!(chunks.len(), chunk_count(len, chunk), "seed {seed}");
        let owned: Vec<Option<Vec<u8>>> = chunks.iter().map(|c| Some(c.to_vec())).collect();
        assert_eq!(join_chunks(&owned, len).unwrap(), data, "seed {seed}");
        // dropping any one chunk breaks the join
        if !owned.is_empty() {
            let mut broken = owned.clone();
            let victim = rng.next_range(broken.len());
            broken[victim] = None;
            assert!(join_chunks(&broken, len).is_none(), "seed {seed}");
        }
    }
}

#[test]
fn prop_quantizers_bounded_error() {
    for seed in 0..100 {
        let mut rng = XorShift64::new(seed + 50_000);
        let group = [8, 16, 32, 64][rng.next_range(4)];
        let n = group * (1 + rng.next_range(64));
        let scale = 10f32.powi(rng.next_range(5) as i32 - 2);
        let v: Vec<f32> = (0..n)
            .map(|_| (rng.next_f64() as f32 - 0.5) * scale)
            .collect();
        for q in [Quantizer::QuantoInt8 { group }, Quantizer::HqqInt8 { group }] {
            let dec = q.decode(&q.encode(&v)).unwrap();
            assert_eq!(dec.len(), v.len());
            let amax = v.iter().fold(0f32, |m, x| m.max(x.abs()));
            let bound = amax / 100.0 + 1e-6; // ~1% of range for int8
            for (a, b) in v.iter().zip(&dec) {
                assert!((a - b).abs() <= bound, "seed {seed} {}: {a} vs {b}", q.name());
            }
        }
    }
}

#[test]
fn prop_radix_tree_matches_hashmap_model() {
    for seed in 0..100 {
        let mut rng = XorShift64::new(seed + 60_000);
        let mut tree = RadixTree::new();
        let mut model = std::collections::HashMap::new();
        for op in 0..400 {
            let len = 1 + rng.next_range(10);
            let key: Vec<u8> = (0..len).map(|_| rng.next_range(3) as u8).collect();
            match rng.next_range(3) {
                0 | 1 => {
                    assert_eq!(
                        tree.insert(&key, op),
                        model.insert(key.clone(), op),
                        "seed {seed} op {op}"
                    );
                }
                _ => {
                    assert_eq!(tree.remove(&key), model.remove(&key), "seed {seed} op {op}");
                }
            }
            assert_eq!(tree.len(), model.len());
        }
        // spot-check longest_prefix against the model
        for _ in 0..50 {
            let len = 1 + rng.next_range(12);
            let key: Vec<u8> = (0..len).map(|_| rng.next_range(3) as u8).collect();
            let expect = (0..=key.len())
                .rev()
                .find_map(|l| model.get(&key[..l]).map(|v| (l, *v)));
            let got = tree.longest_prefix(&key).map(|(l, v)| (l, *v));
            assert_eq!(got, expect, "seed {seed} key {key:?}");
        }
    }
}

#[test]
fn prop_lru_matches_reference_model() {
    for seed in 0..100 {
        let mut rng = XorShift64::new(seed + 70_000);
        let mut lru = LruTracker::new();
        let mut model: Vec<u32> = Vec::new(); // front = MRU
        for _ in 0..500 {
            let key = rng.next_range(30) as u32;
            match rng.next_range(4) {
                0..=1 => {
                    lru.touch(&key);
                    model.retain(|k| *k != key);
                    model.insert(0, key);
                }
                2 => {
                    let got = lru.pop_lru();
                    let want = model.pop();
                    assert_eq!(got, want, "seed {seed}");
                }
                _ => {
                    let got = lru.remove(&key);
                    let had = model.iter().any(|k| *k == key);
                    model.retain(|k| *k != key);
                    assert_eq!(got, had, "seed {seed}");
                }
            }
            assert_eq!(lru.len(), model.len(), "seed {seed}");
        }
    }
}

#[test]
fn prop_store_never_exceeds_budget() {
    for seed in 0..60 {
        let mut rng = XorShift64::new(seed + 80_000);
        let budget = 500 + rng.next_range(5000);
        let mut store = ChunkStore::new(budget);
        for op in 0..300 {
            let block = BlockHash([rng.next_range(6) as u8; 32]);
            let key = skymemory::kvc::chunk::ChunkKey::new(block, rng.next_range(20) as u32);
            let size = 1 + rng.next_range(budget);
            store.set(key, vec![0xAB; size]);
            assert!(
                store.bytes_used() <= budget,
                "seed {seed} op {op}: {} > {budget}",
                store.bytes_used()
            );
        }
    }
}

#[test]
fn prop_message_codecs_roundtrip_random() {
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 90_000);
        let env = Envelope::new(
            SatId::new(rng.next_range(100) as u16, rng.next_range(100) as u16),
            rng.next_u64(),
        );
        let block = BlockHash([(rng.next_u64() & 0xFF) as u8; 32]);
        let key = skymemory::kvc::chunk::ChunkKey::new(block, rng.next_u64() as u32);
        let req = match rng.next_range(6) {
            0 => Request::Ping,
            1 => Request::Get { key },
            2 => Request::Set {
                key,
                payload: (0..rng.next_range(7000)).map(|_| rng.next_u64() as u8).collect(),
            },
            3 => Request::Evict { block, gossip_ttl: rng.next_range(8) as u8 },
            4 => Request::Migrate {
                to: SatId::new(rng.next_range(50) as u16, rng.next_range(50) as u16),
            },
            _ => Request::Query { block },
        };
        let bytes = encode_request(&env, &req);
        let (e2, r2) = decode_request(&bytes).unwrap();
        assert_eq!((e2, r2), (env.clone(), req), "seed {seed}");

        let resp = match rng.next_range(5) {
            0 => Response::SetOk,
            1 => Response::GetOk {
                payload: (0..rng.next_range(7000)).map(|_| rng.next_u64() as u8).collect(),
            },
            2 => Response::GetMiss,
            3 => Response::QueryOk {
                chunk_ids: (0..rng.next_range(64)).map(|_| rng.next_u64() as u32).collect(),
            },
            _ => Response::EvictOk { dropped: rng.next_u64() as u32 },
        };
        let bytes = encode_response(&env, &resp);
        let (e3, r3) = decode_response(&bytes).unwrap();
        assert_eq!((e3, r3), (env, resp), "seed {seed}");
    }
}

#[test]
fn prop_link_model_one_way_monotone_and_zero_byte_invariant() {
    // one_way_s is monotone non-decreasing in payload bytes and in ISL
    // hops; a zero-byte probe pays pure propagation, so its latency is
    // invariant under bandwidth changes and equals uplink + hops * worst
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 120_000);
        let g = Geometry::new(
            300.0 + rng.next_range(1500) as f64,
            8 + rng.next_range(40),
            4 + rng.next_range(40),
        );
        let mut link = LinkModel::laser_defaults(g);
        link.bandwidth_bps = [1e7, 1e8, 1e9, 2.4e9][rng.next_range(4)];
        let cells = (rng.next_range(4), rng.next_range(4));
        let hops = rng.next_range(20);
        let b1 = rng.next_range(10_000);
        let b2 = b1 + rng.next_range(10_000);
        let t1 = link.one_way_s(cells, hops, b1);
        assert!(t1 <= link.one_way_s(cells, hops, b2), "seed {seed}: bytes monotone");
        assert!(t1 <= link.one_way_s(cells, hops + 1, b1), "seed {seed}: hops monotone");
        let mut fat = link;
        fat.bandwidth_bps = link.bandwidth_bps * 8.0;
        assert_eq!(
            link.one_way_s(cells, hops, 0),
            fat.one_way_s(cells, hops, 0),
            "seed {seed}: zero-byte probes ignore bandwidth"
        );
        let prop = g.ground_latency_s(cells.0, cells.1) + hops as f64 * g.worst_hop_latency_s();
        assert!(
            (link.one_way_s(cells, hops, 0) - prop).abs() < 1e-12,
            "seed {seed}: zero bytes = pure propagation"
        );
    }
}

/// Build one deterministic sched stack (fresh fleet each call, so two
/// identically-seeded stacks replay identically).
fn sched_stack(window: usize) -> NetScheduler {
    let torus = Torus::new(7, 13);
    let fleet = Arc::new(Fleet::new(torus, 10 << 20, EvictionPolicy::Lazy));
    let center = SatId::new(3, 6);
    let los = LosGrid::new(center, 2, 2);
    let ground = GroundView::new(center, &los, torus.sats_per_plane);
    let mut link = LinkModel::laser_defaults(Geometry::new(550.0, 13, 7));
    link.bandwidth_bps = 1e8;
    link.sleep_scale = 0.0;
    let inproc: Arc<dyn Transport> =
        Arc::new(InProcTransport::new(fleet, ground, Some(link)));
    NetScheduler::new(inproc, SchedConfig { window })
}

#[test]
fn prop_sched_completion_independent_of_submission_order() {
    // the tie-break determinism contract: a batch's outcome (per-transfer
    // completion times, payloads, makespan — hence completion *order*) is
    // a function of the transfer set, not of the order transfers were
    // pushed into the batch
    for seed in 0..60 {
        let mut rng = XorShift64::new(seed + 130_000);
        let torus = Torus::new(7, 13);
        let window = 1 + rng.next_range(4);
        let n = 1 + rng.next_range(60);
        // the deterministic transfer set: (tag, dest, payload)
        let specs: Vec<(u64, SatId, Vec<u8>)> = (0..n)
            .map(|i| {
                let dest = SatId::new(
                    rng.next_range(torus.planes) as u16,
                    rng.next_range(torus.sats_per_plane) as u16,
                );
                let len = 1 + rng.next_range(2000);
                (i as u64, dest, vec![(i & 0xFF) as u8; len])
            })
            .collect();
        // a shuffled submission order
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            order.swap(i, rng.next_range(i + 1));
        }
        let set_ops = |idx: &[usize]| -> Vec<Transfer> {
            idx.iter()
                .map(|&i| {
                    let (tag, dest, data) = &specs[i];
                    Transfer {
                        tag: *tag,
                        op: ChunkOp::Set {
                            dest: *dest,
                            key: ChunkKey::new(BlockHash([9; 32]), *tag as u32),
                            data: data.clone(),
                        },
                    }
                })
                .collect()
        };
        let get_ops = |idx: &[usize]| -> Vec<Transfer> {
            idx.iter()
                .map(|&i| {
                    let (tag, dest, _) = &specs[i];
                    Transfer {
                        tag: *tag,
                        op: ChunkOp::Get {
                            dest: *dest,
                            key: ChunkKey::new(BlockHash([9; 32]), *tag as u32),
                        },
                    }
                })
                .collect()
        };
        let sorted: Vec<usize> = (0..n).collect();

        let a = sched_stack(window);
        let set_a = a.run_batch(set_ops(&sorted));
        let get_a = a.run_batch(get_ops(&sorted));
        let b = sched_stack(window);
        let set_b = b.run_batch(set_ops(&order));
        let get_b = b.run_batch(get_ops(&order));

        assert_eq!(set_a.makespan_ns, set_b.makespan_ns, "seed {seed}");
        assert_eq!(get_a.makespan_ns, get_b.makespan_ns, "seed {seed}");
        for (oa, ob) in set_a.outcomes.iter().zip(&set_b.outcomes) {
            assert_eq!(oa.tag, ob.tag, "seed {seed}");
            assert_eq!(oa.completion_ns, ob.completion_ns, "seed {seed} tag {}", oa.tag);
            assert_eq!(oa.result, ChunkResult::Stored, "seed {seed}");
            assert_eq!(ob.result, ChunkResult::Stored, "seed {seed}");
        }
        for (oa, ob) in get_a.outcomes.iter().zip(&get_b.outcomes) {
            assert_eq!(oa.tag, ob.tag, "seed {seed}");
            assert_eq!(oa.completion_ns, ob.completion_ns, "seed {seed} tag {}", oa.tag);
            assert_eq!(oa.result, ob.result, "seed {seed} tag {}", oa.tag);
            assert!(
                matches!(oa.result, ChunkResult::Got(Some(_))),
                "seed {seed}: every Set must be readable back"
            );
        }
        // completion *order* (by time, tag tie-break) is identical too
        let order_of = |r: &skymemory::net::sched::BatchReport| {
            let mut v: Vec<(u64, u64)> =
                r.outcomes.iter().map(|o| (o.completion_ns, o.tag)).collect();
            v.sort_unstable();
            v
        };
        assert_eq!(order_of(&get_a), order_of(&get_b), "seed {seed}");
    }
}

#[test]
fn prop_bench_percentiles_match_nearest_rank_oracle() {
    // the bench harness's summary statistics against a from-scratch
    // nearest-rank oracle: for N sorted samples the p-th percentile is
    // the sample at 1-based rank ceil(p * N); and the summary is always
    // internally ordered min <= p50 <= p95 <= p99 <= max
    use skymemory::util::bench::summarize;
    use std::time::Duration;
    let oracle = |sorted: &[Duration], p: f64| {
        let rank = (sorted.len() as f64 * p).ceil() as usize;
        sorted[rank.clamp(1, sorted.len()) - 1]
    };
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 140_000);
        let n = 1 + rng.next_range(400);
        let samples: Vec<Duration> =
            (0..n).map(|_| Duration::from_nanos(rng.next_range(1_000_000) as u64)).collect();
        let mut sorted = samples.clone();
        sorted.sort_unstable();
        let r = summarize("oracle", samples);
        assert_eq!(r.iters, n, "seed {seed}");
        assert_eq!(r.min, sorted[0], "seed {seed}");
        assert_eq!(r.max, sorted[n - 1], "seed {seed}");
        for (p, got) in [(0.50, r.p50), (0.95, r.p95), (0.99, r.p99)] {
            assert_eq!(got, oracle(&sorted, p), "seed {seed} n {n} p {p}");
        }
        assert!(
            r.min <= r.p50 && r.p50 <= r.p95 && r.p95 <= r.p99 && r.p99 <= r.max,
            "seed {seed}: percentiles must be ordered"
        );
    }
}

#[test]
fn prop_store_footprint_monotone_under_inserts_and_shrinks_on_eviction() {
    // the footprint estimate is a pure function of contents: it never
    // decreases while distinct chunks are inserted, never increases while
    // blocks are evicted, and returns exactly to the empty-store estimate
    // after drain_all
    for seed in 0..60 {
        let mut rng = XorShift64::new(seed + 150_000);
        let empty = ChunkStore::new(1 << 30).mem_footprint();
        let mut store = ChunkStore::new(1 << 30);
        let mut blocks = Vec::new();
        let mut prev = store.mem_footprint().total();
        for b in 0..(1 + rng.next_range(12)) {
            let block = BlockHash([b as u8; 32]);
            blocks.push(block);
            for c in 0..(1 + rng.next_range(6)) {
                let purged = store.set(
                    skymemory::kvc::chunk::ChunkKey::new(block, c as u32),
                    vec![0xCD; 1 + rng.next_range(512)],
                );
                assert!(purged.is_empty(), "seed {seed}: budget must never purge");
                let total = store.mem_footprint().total();
                assert!(total >= prev, "seed {seed}: insert shrank the estimate");
                prev = total;
            }
        }
        // shuffled eviction order
        for i in (1..blocks.len()).rev() {
            blocks.swap(i, rng.next_range(i + 1));
        }
        for block in &blocks {
            assert!(store.evict_block(*block) > 0, "seed {seed}");
            let total = store.mem_footprint().total();
            assert!(total <= prev, "seed {seed}: eviction grew the estimate");
            prev = total;
        }
        assert_eq!(store.mem_footprint(), empty, "seed {seed}: must return to empty");
        assert_eq!(store.bytes_used(), 0, "seed {seed}");
        // drain_all from a refilled store also lands exactly on empty
        store.set(skymemory::kvc::chunk::ChunkKey::new(BlockHash([99; 32]), 0), vec![1; 64]);
        let _ = store.drain_all();
        assert_eq!(store.mem_footprint(), empty, "seed {seed}: drain_all must zero it");
    }
}

#[test]
fn prop_index_footprint_monotone_under_inserts_and_shrinks_on_remove() {
    for seed in 0..60 {
        let mut rng = XorShift64::new(seed + 160_000);
        let empty = BlockIndex::new().mem_footprint();
        let mut index = BlockIndex::new();
        let n = 1 + rng.next_range(24);
        let hashes: Vec<BlockHash> = (0..n)
            .map(|_| BlockHash([(rng.next_u64() & 0xFF) as u8; 32]))
            .collect();
        let meta = BlockMeta { num_chunks: 1, kvc_len: 64, write_epoch: 0, quantizer_id: 0 };
        let mut prev = index.mem_footprint().total();
        for i in 0..n {
            index.insert(&hashes[..=i], meta);
            let total = index.mem_footprint().total();
            assert!(total >= prev, "seed {seed} prefix {i}: insert shrank the estimate");
            prev = total;
        }
        // remove prefixes in a shuffled order (every prefix length is a
        // distinct key, so each remove drops exactly one entry)
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            order.swap(i, rng.next_range(i + 1));
        }
        for &i in &order {
            let _ = index.remove(&hashes[..=i]);
            let total = index.mem_footprint().total();
            assert!(total <= prev, "seed {seed} prefix {i}: remove grew the estimate");
            prev = total;
        }
        assert_eq!(index.mem_footprint(), empty, "seed {seed}: must return to empty");
        assert!(index.is_empty(), "seed {seed}");
    }
}

#[test]
fn prop_same_seed_runs_render_identical_memory_objects() {
    // the memory plane is part of the deterministic report contract: two
    // runs of the same seeded scenario render byte-identical `memory`
    // JSON, single-shell and federated alike
    use skymemory::sim::harness::{run_federated_scenario, run_scenario};
    use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};
    for seed in [7u64, 42] {
        let render = || {
            let json = run_scenario(&ScenarioSpec::paper_19x5(seed)).to_json();
            json.get("memory").expect("report carries a memory object").to_string()
        };
        let a = render();
        assert!(a.contains("\"bytes_per_cached_token\""), "seed {seed}");
        assert_eq!(a, render(), "seed {seed}: memory object must be byte-stable");
    }
    let render = || {
        let spec = FederatedScenarioSpec::federated_tri_shell(42);
        let json = run_federated_scenario(&spec).to_json();
        json.get("memory").expect("federated report carries a memory object").to_string()
    };
    let a = render();
    assert!(a.contains("\"resident_copies\""), "per-shell residency must be rendered");
    assert_eq!(a, render(), "federated memory object must be byte-stable");
}

#[test]
fn prop_forked_replay_is_byte_identical_and_refs_return_to_zero() {
    // the kvc::session sharing contract, across random shapes: (1) a
    // forked session extended with fresh turns carries exactly the
    // chained hashes of a fresh session over the concatenated stream —
    // sharing never changes a byte of what the cache stores; (2) the
    // fork completes strictly fewer new blocks than the fresh replay
    // (the shared prefix is never re-stored); (3) after every session
    // drops — in any order — the refcount table is exactly empty.
    use skymemory::kvc::session::SessionManager;
    for seed in 0..150 {
        let mut rng = XorShift64::new(seed + 170_000);
        let bs = 1 + rng.next_range(16);
        let m = SessionManager::new(bs);
        let prefix_blocks = 1 + rng.next_range(6);
        let prefix: Vec<i32> =
            (0..prefix_blocks * bs).map(|_| rng.next_range(1 << 15) as i32).collect();
        let (parent, parent_new) = m.create(&prefix);
        assert_eq!(parent_new.len(), prefix_blocks, "seed {seed}");
        let child = m.fork(parent);
        let ext_blocks = 1 + rng.next_range(5);
        let ext: Vec<i32> = (0..ext_blocks * bs + rng.next_range(bs))
            .map(|_| rng.next_range(1 << 15) as i32)
            .collect();
        let child_new = m.extend(child, &ext);
        let mut full = prefix.clone();
        full.extend_from_slice(&ext);
        let (fresh, fresh_new) = m.create(&full);
        // (1) byte-identical chains: fork+extend == fresh == oracle
        assert_eq!(m.chain(child), m.chain(fresh), "seed {seed}");
        assert_eq!(m.chain(child), block_hashes(&full, bs), "seed {seed}");
        // (2) the fork completed only the extension's blocks
        assert_eq!(fresh_new.len(), prefix_blocks + ext_blocks, "seed {seed}");
        assert_eq!(child_new.len(), ext_blocks, "seed {seed}");
        assert!(
            child_new.len() < fresh_new.len(),
            "seed {seed}: the fork must store strictly less"
        );
        // the shared prefix is multiply referenced while everyone lives
        let refs = m.refs();
        for h in &m.chain(parent) {
            assert!(refs.refs(h) >= 2, "seed {seed}: prefix blocks must be shared");
        }
        // (3) shuffled drop order: every reference comes back exactly once
        let mut ids = vec![parent, child, fresh];
        for i in (1..ids.len()).rev() {
            ids.swap(i, rng.next_range(i + 1));
        }
        for id in ids {
            m.drop_session(id);
        }
        assert_eq!(refs.total_refs(), 0, "seed {seed}");
        assert_eq!(refs.unique_blocks(), 0, "seed {seed}");
        assert_eq!(m.live_sessions(), 0, "seed {seed}");
    }
}

#[test]
fn prop_decode_rejects_random_corruption() {
    // flip random bytes in valid messages: decode must error or return a
    // different-but-valid message, never panic
    for seed in 0..CASES {
        let mut rng = XorShift64::new(seed + 95_000);
        let env = Envelope::new(SatId::new(1, 2), 42);
        let req = Request::Set {
            key: skymemory::kvc::chunk::ChunkKey::new(BlockHash([7; 32]), 3),
            payload: vec![1, 2, 3, 4, 5],
        };
        let mut bytes = encode_request(&env, &req);
        let n_flips = 1 + rng.next_range(4);
        for _ in 0..n_flips {
            let i = rng.next_range(bytes.len());
            bytes[i] ^= 1 << rng.next_range(8);
        }
        let _ = decode_request(&bytes); // must not panic
        let _ = decode_response(&bytes);
    }
}
