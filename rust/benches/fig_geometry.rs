//! Bench + reproduction harness for Figures 1 & 2 (and Table 1's LEO
//! rows): intra-plane ISL latency vs altitude and plane size, straight
//! from eq. (1).  Prints the same series the paper plots, then times the
//! geometry hot functions.
//!
//! Writes `BENCH_fig_geometry.json`: iteration/shape counters in the
//! deterministic namespace, wall-clock stats in timing.

use skymemory::constellation::geometry::{chord_distance_km, Geometry, LIGHT_SPEED_KM_S};
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("fig_geometry", smoke);
    let pick = |s: usize, f: usize| if smoke { s } else { f };

    println!("=== Figure 1 / Figure 2: intra-plane ISL latency (ms) ===");
    println!(
        "{:>6} {}",
        "M \\ h",
        (0..8).map(|i| format!("{:>8}", 160 + i * 260)).collect::<String>()
    );
    for m in [10usize, 15, 20, 30, 40, 50, 60] {
        let mut row = format!("{m:>6} ");
        for i in 0..8 {
            let h = 160.0 + i as f64 * 260.0;
            row += &format!("{:>8.3}", chord_distance_km(h, m) / LIGHT_SPEED_KM_S * 1e3);
        }
        println!("{row}");
    }
    println!("\npaper claims (§2): ~50+ satellites per plane give low-ms hops;");
    println!(
        "  50 sats @ 550 km: {:.3} ms",
        chord_distance_km(550.0, 50) / LIGHT_SPEED_KM_S * 1e3
    );
    println!(
        "  80 sats @ 550 km: {:.3} ms",
        chord_distance_km(550.0, 80) / LIGHT_SPEED_KM_S * 1e3
    );

    println!("\n=== Table 1 LEO rows (model cross-check) ===");
    for (name, g) in [
        ("19x5 testbed shell @550km", Geometry::new(550.0, 19, 5)),
        ("dense 60x60 @550km", Geometry::new(550.0, 60, 60)),
    ] {
        println!(
            "{name}: intra {:.3} ms, inter {:.3} ms, ground(overhead) {:.3} ms",
            g.intra_plane_latency_s() * 1e3,
            g.inter_plane_latency_s() * 1e3,
            g.ground_latency_s(0, 0) * 1e3
        );
    }
    // 7 plane sizes x 24 altitudes in the full-sweep bench below
    art.counter("sweep_plane_sizes", 7);
    art.counter("sweep_altitudes", 24);

    println!("\n=== timings ===");
    let g = Geometry::new(550.0, 19, 5);
    let r = Bencher::new("geometry::worst_hop_latency_s")
        .fixed_iters(pick(8192, 65536))
        .batch(64)
        .run(|| {
            std::hint::black_box(g.worst_hop_latency_s());
        });
    println!("{}", r.report());
    art.push(&r);
    let r = Bencher::new("geometry::ground_latency_s(2,2)")
        .fixed_iters(pick(8192, 65536))
        .batch(64)
        .run(|| {
            std::hint::black_box(g.ground_latency_s(2, 2));
        });
    println!("{}", r.report());
    art.push(&r);
    let r = Bencher::new("fig1 full sweep (7 M x 24 h)")
        .fixed_iters(pick(1024, 8192))
        .batch(8)
        .run(|| {
            for m in [10usize, 15, 20, 30, 40, 50, 60] {
                for i in 0..24 {
                    std::hint::black_box(chord_distance_km(160.0 + i as f64 * 80.0, m));
                }
            }
        });
    println!("{}", r.report());
    art.push(&r);

    let path = art.write().expect("write BENCH_fig_geometry.json");
    println!("wrote {}", path.display());
}
