//! Bench harness for the scenario subsystem: times one full end-to-end
//! run of every built-in scenario (fleet construction, workload serving,
//! per-epoch migration and failure injection included), then uses the
//! micro-bench harness on the small paper shape to expose run-to-run
//! variance of the hot loop.

use skymemory::sim::harness::{run_federated_scenario, run_scenario};
use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};
use skymemory::util::bench::Bencher;
use std::time::{Duration, Instant};

fn main() {
    println!("=== scenario end-to-end timings (seed 42) ===");
    for spec in ScenarioSpec::builtin(42) {
        let t0 = Instant::now();
        let report = run_scenario(&spec);
        let wall = t0.elapsed();
        println!(
            "{:<16} {:>5} sats  {:>2} epochs  {:>4} reqs  hit {:>6.1}%  \
             migrated {:>6}  blackholed {:>4}  isl {:>9} hop-bytes  wall {:?}",
            report.name,
            spec.torus().len(),
            report.epochs,
            report.requests,
            100.0 * report.block_hit_rate,
            report.migrated_chunks,
            report.blackholed_requests,
            report.isl_bytes,
            wall
        );
    }

    println!("\n=== federated end-to-end (seed 42) ===");
    for fed in [
        FederatedScenarioSpec::federated_dual_shell(42),
        FederatedScenarioSpec::federated_tri_shell(42),
    ] {
        let t0 = Instant::now();
        let report = run_federated_scenario(&fed);
        let wall = t0.elapsed();
        println!(
            "{:<22} {:>5} sats  {:>2} epochs  {:>4} reqs  hit {:>6.1}%  \
             handovers {:>4}  replicas {:>3}  preplaced {:>3}  inter-shell {:>8} B  spill {:>4}  wall {:?}",
            report.name,
            fed.shells.iter().map(|s| s.torus().len()).sum::<usize>(),
            report.epochs,
            report.requests,
            100.0 * report.block_hit_rate,
            report.handovers,
            report.replicated_blocks,
            report.preplaced_blocks,
            report.inter_shell_bytes,
            report.spillovers,
            wall
        );
        for sh in &report.shells {
            println!(
                "  {:<14} {:>5} sats  stored {:>5}  hit {:>6.1}%  replica hits {:>4}  \
                 evicted {:>5}  failed sats {:>4}",
                sh.name,
                sh.planes * sh.sats_per_plane,
                sh.blocks_stored,
                100.0 * sh.hit_rate,
                sh.replica_hits,
                sh.evicted_chunks,
                sh.failed_satellites
            );
        }
    }
    // the tri-shell acceptance comparison: replicated vs re-homing-only
    let tri = FederatedScenarioSpec::federated_tri_shell(42);
    let t0 = Instant::now();
    let replicated = run_federated_scenario(&tri);
    let rehoming = run_federated_scenario(&tri.rehoming_baseline());
    println!(
        "replicated {:>6.1}% vs re-homing-only {:>6.1}% under the correlated plan ({:?} for both)",
        100.0 * replicated.block_hit_rate,
        100.0 * rehoming.block_hit_rate,
        t0.elapsed()
    );

    println!("\n=== paper-19x5 repeatability (micro-bench) ===");
    let mut small = ScenarioSpec::paper_19x5(42);
    small.epochs = 2;
    small.requests_per_epoch = 8;
    let r = Bencher::new("run_scenario paper-19x5 (2 epochs x 8 reqs)")
        .warmup(Duration::from_millis(50))
        .measure(Duration::from_millis(500))
        .run(|| {
            std::hint::black_box(run_scenario(&small));
        });
    println!("{}", r.report());
}
