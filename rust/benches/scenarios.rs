//! Bench harness for the scenario subsystem: times one full end-to-end
//! run of every built-in scenario (fleet construction, workload serving,
//! per-epoch migration and failure injection included), then uses the
//! micro-bench harness on the small paper shape to expose run-to-run
//! variance of the hot loop.
//!
//! Writes `BENCH_scenarios.json`: every scenario's counters (requests,
//! hits, migrations, ISL bytes, scheduler transfers and virtual time)
//! are deterministic at a fixed seed and go into the artifact's
//! deterministic namespace; wall-clock numbers go into timing.

use skymemory::sim::harness::{run_federated_scenario, run_scenario};
use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};
use skymemory::util::bench::{smoke_mode, slug, BenchArtifact, Bencher};
use std::time::Instant;

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("scenarios", smoke);

    println!("=== scenario end-to-end timings (seed 42) ===");
    let builtin = ScenarioSpec::builtin(42);
    art.counter("builtin_scenarios", builtin.len() as u64);
    for spec in builtin {
        let t0 = Instant::now();
        let report = run_scenario(&spec);
        let wall = t0.elapsed();
        println!(
            "{:<16} {:>5} sats  {:>2} epochs  {:>4} reqs  hit {:>6.1}%  \
             migrated {:>6}  blackholed {:>4}  isl {:>9} hop-bytes  wall {:?}",
            report.name,
            spec.torus().len(),
            report.epochs,
            report.requests,
            100.0 * report.block_hit_rate,
            report.migrated_chunks,
            report.blackholed_requests,
            report.isl_bytes,
            wall
        );
        let p = slug(&report.name);
        art.counter(&format!("{p}.requests"), report.requests);
        art.counter(&format!("{p}.blocks_hit"), report.blocks_hit);
        art.counter(&format!("{p}.migrated_chunks"), report.migrated_chunks);
        art.counter(&format!("{p}.isl_bytes"), report.isl_bytes);
        art.counter(&format!("{p}.sched_transfers"), report.sched.transfers);
        art.counter(&format!("{p}.sched_virtual_time_ns"), report.sched.virtual_ns);
        art.timing_ns(&format!("{p}.wall_ns"), wall.as_nanos() as u64);
    }

    println!("\n=== federated end-to-end (seed 42) ===");
    for fed in [
        FederatedScenarioSpec::federated_dual_shell(42),
        FederatedScenarioSpec::federated_tri_shell(42),
    ] {
        let t0 = Instant::now();
        let report = run_federated_scenario(&fed);
        let wall = t0.elapsed();
        println!(
            "{:<22} {:>5} sats  {:>2} epochs  {:>4} reqs  hit {:>6.1}%  \
             handovers {:>4}  replicas {:>3}  preplaced {:>3}  inter-shell {:>8} B  spill {:>4}  wall {:?}",
            report.name,
            fed.shells.iter().map(|s| s.torus().len()).sum::<usize>(),
            report.epochs,
            report.requests,
            100.0 * report.block_hit_rate,
            report.handovers,
            report.replicated_blocks,
            report.preplaced_blocks,
            report.inter_shell_bytes,
            report.spillovers,
            wall
        );
        for sh in &report.shells {
            println!(
                "  {:<14} {:>5} sats  stored {:>5}  hit {:>6.1}%  replica hits {:>4}  \
                 evicted {:>5}  failed sats {:>4}",
                sh.name,
                sh.planes * sh.sats_per_plane,
                sh.blocks_stored,
                100.0 * sh.hit_rate,
                sh.replica_hits,
                sh.evicted_chunks,
                sh.failed_satellites
            );
        }
        let p = slug(&report.name);
        art.counter(&format!("{p}.requests"), report.requests);
        art.counter(&format!("{p}.blocks_hit"), report.blocks_hit);
        art.counter(&format!("{p}.handovers"), report.handovers);
        art.counter(&format!("{p}.replicated_blocks"), report.replicated_blocks);
        art.counter(&format!("{p}.inter_shell_bytes"), report.inter_shell_bytes);
        art.timing_ns(&format!("{p}.wall_ns"), wall.as_nanos() as u64);
    }
    // the tri-shell acceptance comparison: replicated vs re-homing-only
    let tri = FederatedScenarioSpec::federated_tri_shell(42);
    let t0 = Instant::now();
    let replicated = run_federated_scenario(&tri);
    let rehoming = run_federated_scenario(&tri.rehoming_baseline());
    println!(
        "replicated {:>6.1}% vs re-homing-only {:>6.1}% under the correlated plan ({:?} for both)",
        100.0 * replicated.block_hit_rate,
        100.0 * rehoming.block_hit_rate,
        t0.elapsed()
    );
    art.counter("tri_replicated.blocks_hit", replicated.blocks_hit);
    art.counter("tri_rehoming.blocks_hit", rehoming.blocks_hit);

    println!("\n=== paper-19x5 repeatability (micro-bench) ===");
    let mut small = ScenarioSpec::paper_19x5(42);
    small.epochs = 2;
    small.requests_per_epoch = 8;
    let r = Bencher::new("run_scenario paper-19x5 (2 epochs x 8 reqs)")
        .fixed_iters(if smoke { 5 } else { 20 })
        .run(|| {
            std::hint::black_box(run_scenario(&small));
        });
    println!("{}", r.report());
    art.push(&r);

    let path = art.write().expect("write BENCH_scenarios.json");
    println!("wrote {}", path.display());
}
