//! Bench + reproduction harness for Figure 16: worst-case KVC latency
//! across strategies x altitude x servers x chunk-processing x KVC size.
//! Prints the paper's series (who wins, by how much, where the knees are)
//! and times the simulator.
//!
//! Writes `BENCH_fig16_strategies.json`: sweep shape counters in the
//! deterministic namespace, wall-clock stats in timing.

use skymemory::mapping::Strategy;
use skymemory::sim::latency::{figure16_sweep, worst_case_latency};
use skymemory::sim::SimConfig;
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("fig16_strategies", smoke);
    let pick = |s: usize, f: usize| if smoke { s } else { f };

    println!("=== Figure 16: max latency across parameters and strategies ===");
    println!(
        "{:<26} {:>8} {:>8} {:>7} {:>8} {:>10}",
        "strategy", "alt(km)", "servers", "kvc", "proc(ms)", "total(s)"
    );
    // the headline series: latency vs altitude per strategy (81 servers,
    // 21 MB, 2 ms — the dense corner of Table 2)
    for st in Strategy::ALL {
        for alt in SimConfig::altitude_sweep() {
            let cfg = SimConfig { strategy: st, altitude_km: alt, ..Default::default() };
            let b = worst_case_latency(&cfg);
            println!(
                "{:<26} {:>8} {:>8} {:>7} {:>8} {:>10.4}",
                st.name(),
                alt,
                cfg.n_servers,
                "21MB",
                cfg.chunk_processing_s * 1e3,
                b.total_s
            );
        }
    }
    art.counter("strategies", Strategy::ALL.len() as u64);
    art.counter("altitude_points", SimConfig::altitude_sweep().len() as u64);
    art.counter("server_points", SimConfig::server_sweep().len() as u64);

    // server scaling (the 8x claim)
    println!("\n--- server scaling at 550 km, 21 MB, 20 ms processing ---");
    for st in Strategy::ALL {
        print!("{:<26}", st.name());
        for n in SimConfig::server_sweep() {
            let cfg = SimConfig {
                strategy: st,
                n_servers: n,
                chunk_processing_s: 0.02,
                ..Default::default()
            };
            print!(" {:>9.3}s", worst_case_latency(&cfg).total_s);
        }
        println!();
    }
    print!("\n{}", skymemory::repro::fig16_summary());
    art.counter("sweep_cells", figure16_sweep().len() as u64);

    println!("\n=== timings ===");
    let cfg = SimConfig::default();
    let r = Bencher::new("worst_case_latency (81 servers)")
        .fixed_iters(pick(2048, 16384))
        .batch(32)
        .run(|| {
            std::hint::black_box(worst_case_latency(&cfg));
        });
    println!("{}", r.report());
    art.push(&r);
    let r = Bencher::new("figure16 full sweep (336 cells)").fixed_iters(pick(5, 50)).run(|| {
        std::hint::black_box(figure16_sweep());
    });
    println!("{}", r.report());
    art.push(&r);

    let path = art.write().expect("write BENCH_fig16_strategies.json");
    println!("wrote {}", path.display());
}
