//! Bench: the deterministic memory-footprint model vs measured heap use.
//!
//! Sweeps cached-prefix counts: for each `n` it builds one prefix chain
//! of `n` blocks — every block is `CHUNKS_PER_BLOCK` chunks of
//! `CHUNK_BYTES` in a [`ChunkStore`] plus one [`BlockIndex`] entry per
//! prefix — then reads the [`MemFootprint`] estimates and times both the
//! build and the footprint rollup.
//!
//! `BENCH_mem.json` layout:
//!
//! * deterministic namespace — hand-predictable counters per sweep point
//!   (`prefix{n}.payload_bytes = n * CHUNKS_PER_BLOCK * CHUNK_BYTES`,
//!   `prefix{n}.cached_tokens = n * TOKENS_PER_BLOCK`,
//!   `prefix{n}.indexed_blocks = n`, and the compacted two-layer index's
//!   `prefix{n}.frozen_index_bytes = 60n + 4` for these zero-lcp keys)
//!   that the committed baseline gates exactly, plus the model's
//!   estimate totals (`estimate_*_bytes`), which are deterministic per
//!   binary but depend on struct layout, so the baseline leaves them
//!   untracked (only-in-new keys are neutral).
//! * timing namespace — wall-clock build/rollup stats, and under
//!   `--features mem-profile` the counting allocator's measured
//!   live/peak bytes and allocation counts for the same builds.
//!
//! With `mem-profile` enabled the bench also validates the model: the
//! estimated total for each sweep point must land within a loose factor
//! of the measured live-byte delta (the model charges flat
//! [`ALLOC_OVERHEAD`](skymemory::obs::mem::ALLOC_OVERHEAD) per
//! allocation and counts elements rather than capacities, so exact
//! equality is not expected — order-of-magnitude agreement is the
//! claim).
//!
//! ```text
//! cargo bench --bench mem [-- --smoke]
//! cargo bench --bench mem --features mem-profile [-- --smoke]
//! ```

use skymemory::kvc::block::BlockHash;
use skymemory::kvc::chunk::ChunkKey;
use skymemory::kvc::frozen::FrozenBlockIndex;
use skymemory::kvc::radix::{BlockIndex, BlockMeta};
use skymemory::obs::mem::{FootprintEstimate, MemFootprint};
use skymemory::satellite::store::ChunkStore;
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};

/// Use the counting allocator for the whole process when profiling.
#[cfg(feature = "mem-profile")]
#[global_allocator]
static COUNTING: skymemory::obs::mem::profile::CountingAlloc =
    skymemory::obs::mem::profile::CountingAlloc;

/// Payload bytes per chunk — fixed so `payload_bytes` is hand-checkable.
const CHUNK_BYTES: usize = 256;
/// Chunks per cached block (paper-style striping unit).
const CHUNKS_PER_BLOCK: usize = 4;
/// Tokens represented by one cached block (KvcConfig default).
const TOKENS_PER_BLOCK: u64 = 32;

/// Sweep of cached-prefix lengths (number of blocks in the chain).
fn sweep(smoke: bool) -> &'static [usize] {
    if smoke {
        &[16, 64]
    } else {
        &[64, 256, 1024]
    }
}

fn hash_for(i: usize) -> BlockHash {
    let mut bytes = [0u8; 32];
    bytes[..8].copy_from_slice(&(i as u64).to_le_bytes());
    BlockHash(bytes)
}

fn block_meta() -> BlockMeta {
    BlockMeta {
        num_chunks: CHUNKS_PER_BLOCK as u32,
        kvc_len: (CHUNKS_PER_BLOCK * CHUNK_BYTES) as u32,
        write_epoch: 0,
        quantizer_id: 0,
    }
}

/// Build one prefix chain of `n` cached blocks: store holds the chunk
/// payloads, index records every prefix `[0..=i]` as cached.
fn build_chain(n: usize) -> (ChunkStore, BlockIndex) {
    let mut store = ChunkStore::new(1 << 30);
    let mut index = BlockIndex::new();
    let hashes: Vec<BlockHash> = (0..n).map(hash_for).collect();
    for (i, hash) in hashes.iter().enumerate() {
        for c in 0..CHUNKS_PER_BLOCK {
            let purged = store.set(ChunkKey::new(*hash, c as u32), vec![0xAB; CHUNK_BYTES]);
            assert!(purged.is_empty(), "budget is sized to never purge");
        }
        index.insert(&hashes[..=i], block_meta());
    }
    (store, index)
}

/// Build the two-layer index over the same chain and freeze it: every
/// prefix lands in the radix delta, one compaction collapses them all
/// into the arena's three flat allocations keyed by terminal hash.
fn build_frozen_chain(n: usize) -> FrozenBlockIndex {
    let hashes: Vec<BlockHash> = (0..n).map(hash_for).collect();
    let mut index = FrozenBlockIndex::new();
    for i in 0..n {
        index.insert(&hashes[..=i], block_meta());
    }
    assert!(index.compact(), "a non-empty delta must freeze");
    assert_eq!(index.longest_cached_prefix(&hashes).map(|(k, _)| k), Some(n));
    index
}

fn footprint_of(store: &ChunkStore, index: &BlockIndex) -> FootprintEstimate {
    let mut est = store.mem_footprint();
    est.add(index.mem_footprint());
    est
}

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("mem", smoke);

    println!("=== footprint model over cached-prefix chains ===");
    println!(
        "=== {} chunks x {} B per block, {} tokens per block ===",
        CHUNKS_PER_BLOCK, CHUNK_BYTES, TOKENS_PER_BLOCK
    );

    let mut prev_total = 0u64;
    for &n in sweep(smoke) {
        #[cfg(feature = "mem-profile")]
        let before = skymemory::obs::mem::profile::snapshot();
        let (store, index) = build_chain(n);
        #[cfg(feature = "mem-profile")]
        let after = skymemory::obs::mem::profile::snapshot();

        let est = footprint_of(&store, &index);

        // The model's payload side is exact by construction, and two
        // same-content builds must agree byte-for-byte.
        let payload = (n * CHUNKS_PER_BLOCK * CHUNK_BYTES) as u64;
        assert_eq!(est.payload_bytes, payload, "payload model must be exact");
        assert_eq!(index.len(), n, "one index entry per prefix");
        let (store2, index2) = build_chain(n);
        assert_eq!(footprint_of(&store2, &index2), est, "estimate must be deterministic");
        assert!(est.total() > prev_total, "estimate must grow with the chain");
        prev_total = est.total();

        let cached_tokens = n as u64 * TOKENS_PER_BLOCK;
        println!(
            "prefix n={n:<5} payload {payload:>8} B  index {:>7} B  overhead {:>7} B  \
             total {:>8} B  {:.1} B/token",
            est.index_bytes,
            est.overhead_bytes,
            est.total(),
            est.total() as f64 / cached_tokens as f64
        );

        // Hand-predictable counters: gated exactly by the committed
        // baseline.
        art.counter(&format!("prefix{n}.payload_bytes"), payload);
        art.counter(&format!("prefix{n}.cached_tokens"), cached_tokens);
        art.counter(&format!("prefix{n}.indexed_blocks"), n as u64);
        // Model totals: deterministic per binary, layout-dependent, so
        // deliberately absent from the baseline.
        art.counter(&format!("prefix{n}.estimate_index_bytes"), est.index_bytes);
        art.counter(&format!("prefix{n}.estimate_overhead_bytes"), est.overhead_bytes);
        art.counter(&format!("prefix{n}.estimate_total_bytes"), est.total());

        // The frozen two-layer index over the same chain, post-compaction:
        // three flat allocations instead of one boxed radix node per
        // prefix.  The chain's keys share no byte-0 prefix, so the arena
        // is exactly `60n + 4` bytes — hand-predictable and gated.
        #[cfg(feature = "mem-profile")]
        let fz_before = skymemory::obs::mem::profile::snapshot();
        let frozen = build_frozen_chain(n);
        #[cfg(feature = "mem-profile")]
        let fz_after = skymemory::obs::mem::profile::snapshot();
        assert_eq!((frozen.len(), frozen.delta_len()), (n, 0));
        let frozen_est = frozen.mem_footprint();
        assert_eq!(frozen_est.frozen_bytes, frozen_est.index_bytes + frozen_est.overhead_bytes);
        let radix_est = index.mem_footprint();
        assert!(
            frozen_est.total() as f64 <= 0.7 * radix_est.total() as f64,
            "frozen layer must undercut the radix index by >=30%: {} vs {} for n={n}",
            frozen_est.total(),
            radix_est.total()
        );
        println!(
            "prefix n={n:<5} frozen index {:>7} B vs radix {:>7} B ({:.2}x smaller)",
            frozen_est.index_bytes,
            radix_est.total(),
            radix_est.total() as f64 / frozen_est.total().max(1) as f64
        );
        art.counter(&format!("prefix{n}.frozen_index_bytes"), frozen_est.index_bytes);

        #[cfg(feature = "mem-profile")]
        {
            let live = after.live_bytes.saturating_sub(before.live_bytes);
            let allocs = after.allocations - before.allocations;
            let ratio = est.total() as f64 / live.max(1) as f64;
            println!(
                "prefix n={n:<5} measured {live:>8} B live over {allocs:>6} allocations  \
                 estimate/measured {ratio:.2}x"
            );
            art.timing_ns(&format!("prefix{n}.measured_live_bytes"), live);
            art.timing_ns(&format!("prefix{n}.measured_allocations"), allocs);
            art.timing_ns(&format!("prefix{n}.measured_peak_bytes"), after.peak_bytes);
            assert!(
                (0.2..=5.0).contains(&ratio),
                "estimate {} B vs measured {live} B for n={n}: model is off by more than 5x",
                est.total()
            );

            // Allocator-measured frozen build: the compacted index's live
            // bytes must sit within the same loose factor of its model
            // and strictly below a plain per-block BTreeMap of the same
            // chain (the pre-compaction shape the arena replaces).
            let frozen_live = fz_after.live_bytes.saturating_sub(fz_before.live_bytes);
            let frozen_ratio = frozen_est.total() as f64 / frozen_live.max(1) as f64;
            let bt_before = skymemory::obs::mem::profile::snapshot();
            let mut btree: std::collections::BTreeMap<BlockHash, BlockMeta> = Default::default();
            for i in 0..n {
                btree.insert(hash_for(i), block_meta());
            }
            let bt_after = skymemory::obs::mem::profile::snapshot();
            let btree_live = bt_after.live_bytes.saturating_sub(bt_before.live_bytes);
            assert_eq!(btree.len(), n);
            println!(
                "prefix n={n:<5} frozen measured {frozen_live:>7} B live (btree {btree_live:>7} B)  \
                 estimate/measured {frozen_ratio:.2}x"
            );
            art.timing_ns(&format!("prefix{n}.measured_frozen_live_bytes"), frozen_live);
            art.timing_ns(&format!("prefix{n}.measured_btree_live_bytes"), btree_live);
            assert!(
                (0.2..=5.0).contains(&frozen_ratio),
                "frozen estimate {} B vs measured {frozen_live} B for n={n}: model is off by more than 5x",
                frozen_est.total()
            );
            assert!(
                frozen_live < btree_live,
                "frozen layer must beat the plain BTreeMap on measured bytes: \
                 {frozen_live} vs {btree_live} B for n={n}"
            );
        }
    }

    println!("\n=== wall-clock: chain build and footprint rollup ===");
    let &n = sweep(smoke).last().unwrap();
    let iters = if smoke { 8 } else { 32 };
    let build = Bencher::new(format!("mem build chain n={n}"))
        .fixed_iters(iters)
        .bytes_per_iter(n * CHUNKS_PER_BLOCK * CHUNK_BYTES)
        .run(|| {
            let (store, index) = build_chain(n);
            assert_eq!(index.len(), n);
            drop(store);
        });
    println!("{}", build.report());
    art.push(&build);

    let (store, index) = build_chain(n);
    let rollup = Bencher::new(format!("mem footprint rollup n={n}"))
        .fixed_iters(iters * 4)
        .run(|| {
            let est = footprint_of(&store, &index);
            assert!(est.total() > 0);
        });
    println!("{}", rollup.report());
    art.push(&rollup);

    let path = art.write().expect("write BENCH_mem.json");
    println!("wrote {}", path.display());
}
