//! Bench harness for Table 3: end-to-end generation with and without the
//! SkyMemory KVC, per quantizer, over the 19x5 in-process constellation
//! with calibrated link emulation (see examples/e2e_testbed.rs for the
//! calibration rationale).  Requires `make artifacts`.
//!
//! Writes `BENCH_table3_e2e.json` in every case.  When the model
//! artifacts are missing (plain CI checkout) the artifact still comes
//! out valid and diffable: a string label records why the run was
//! skipped — labels are invisible to `skymemory bench --diff`, so a
//! skipped run never false-alarms against a full one's timing-only keys.

use skymemory::constellation::geometry::Geometry;
use skymemory::coordinator::{GenRequest, Stack, StackConfig};
use skymemory::kvc::quantize::Quantizer;
use skymemory::net::transport::LinkModel;
use skymemory::util::bench::{smoke_mode, summarize, BenchArtifact};
use std::time::Duration;

const PROMPT: &str = "We expand the scope of cache memory to include LEO constellations, \
highly distributed systems with thousands of satellites connected with free-space \
optics inter-satellite links, always one hop from any point on earth.";

fn main() -> anyhow::Result<()> {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("table3_e2e", smoke);
    if !skymemory::runtime::model_config::default_artifacts_dir()
        .join("model_config.json")
        .exists()
    {
        eprintln!("artifacts not built — run `make artifacts` first");
        art.label("artifacts", "missing");
        let path = art.write().expect("write BENCH_table3_e2e.json");
        println!("wrote {} (skipped: artifacts missing)", path.display());
        return Ok(());
    }
    art.label("artifacts", "present");
    let runs = if smoke { 3usize } else { 7 };
    art.counter("runs_per_cell", runs as u64);
    art.counter("quantizers", 3);
    art.counter("max_new_tokens", 30);
    println!("=== Table 3 bench: 30-token generation, 19x5 constellation ===");
    for (name, q) in [
        ("optimum-quanto", Quantizer::QuantoInt8 { group: 32 }),
        ("hqq", Quantizer::HqqInt8 { group: 32 }),
        ("f32 (ablation)", Quantizer::F32),
    ] {
        let mut cfg = StackConfig::default();
        cfg.kvc.quantizer = q;
        cfg.kvc.n_servers = 10;
        let mut link = LinkModel::laser_defaults(Geometry::new(550.0, 19, 5));
        link.sleep_scale = 1.0 / 300.0;
        link.bandwidth_bps = 200e6;
        cfg.link = Some(link);
        cfg.n_workers = 1;
        let stack = Stack::build(cfg)?;

        let req = GenRequest { prompt: PROMPT.into(), max_new_tokens: 30, ..Default::default() };
        // warm-up + prime
        let mut nocache = req.clone();
        nocache.use_cache = false;
        stack.router.generate(nocache.clone())?;
        let cold: Vec<Duration> = (0..runs)
            .map(|_| {
                Duration::from_secs_f64(stack.router.generate(nocache.clone()).unwrap().total_s)
            })
            .collect();
        stack.router.generate(req.clone())?; // prime the cache
        let warm: Vec<Duration> = (0..runs)
            .map(|_| Duration::from_secs_f64(stack.router.generate(req.clone()).unwrap().total_s))
            .collect();
        let c = summarize(format!("{name} no-KVC"), cold);
        let w = summarize(format!("{name} KVC"), warm);
        println!("{}", c.report());
        println!("{}", w.report());
        println!(
            "  -> speedup {:.1}% (paper: quanto 21%, hqq 24%)\n",
            100.0 * (1.0 - w.p50.as_secs_f64() / c.p50.as_secs_f64())
        );
        art.push(&c);
        art.push(&w);
    }
    let path = art.write().expect("write BENCH_table3_e2e.json");
    println!("wrote {}", path.display());
    Ok(())
}
