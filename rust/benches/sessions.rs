//! Bench: the kvc::session layer at 10⁵–10⁷ logical concurrent sessions.
//!
//! Drives the [`SessionManager`] directly (no satellites, no harness):
//! for each sweep point `N` it creates one root session per prefix
//! template, forks the roots round-robin into `N` logical sessions, and
//! reads the refcount table and the [`MemFootprint`] metadata estimate.
//! Every fork shares its template's whole 6-block prefix without copying
//! a chunk, so the counters are hand-predictable:
//!
//! * `s{N}.logical_sessions = N + TEMPLATES`
//! * `s{N}.unique_blocks   = TEMPLATES * TEMPLATE_BLOCKS` (forks add none)
//! * `s{N}.total_refs      = (N + TEMPLATES) * TEMPLATE_BLOCKS`
//! * `s{N}.shared_blocks   = TEMPLATES * TEMPLATE_BLOCKS` (all refcount 2+)
//! * `s{N}.hist_top_bucket = TEMPLATES * TEMPLATE_BLOCKS` (all refcount 8+)
//! * `s{N}.refs_after_drop = 0` and `s{N}.unique_after_drop = 0` — every
//!   reference is returned exactly once when the sessions drop
//!
//! the committed `BENCH_sessions.json` baseline gates these exactly.
//! `s{N}.metadata_bytes` (struct-layout dependent) and the Zipfian
//! trace-generator counters (seeded, deterministic run-over-run but not
//! hand-computable) stay out of the baseline: only-in-new keys are
//! neutral.  The bench also asserts the headline scaling claim inline —
//! a forked session costs well under 256 metadata bytes, which is what
//! makes the 10⁷ sweep fit in RAM.
//!
//! ```text
//! cargo bench --bench sessions [-- --smoke]
//! ```

use skymemory::kvc::session::{SessionId, SessionManager, REFCOUNT_BUCKETS};
use skymemory::obs::mem::MemFootprint;
use skymemory::sim::workload::{generate_sessions, SessionWorkloadConfig};
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};

/// Tokens per cached block (KvcConfig / scenario default).
const BLOCK_TOKENS: usize = 32;
/// Distinct prefix templates (Zipf popularity classes).
const TEMPLATES: usize = 4;
/// Blocks per template prefix (192 tokens / 32 per block).
const TEMPLATE_BLOCKS: usize = 6;

/// Sweep of logical concurrent session counts.
fn sweep(smoke: bool) -> &'static [usize] {
    if smoke {
        &[100_000]
    } else {
        &[100_000, 1_000_000, 10_000_000]
    }
}

fn template_tokens(t: usize) -> Vec<i32> {
    (0..TEMPLATE_BLOCKS * BLOCK_TOKENS).map(|i| i as i32 * 31 + t as i32).collect()
}

/// One root per template, then `n` forks round-robin across the roots.
fn populate(n: usize) -> (SessionManager, Vec<SessionId>, Vec<SessionId>) {
    let m = SessionManager::new(BLOCK_TOKENS);
    let roots: Vec<SessionId> = (0..TEMPLATES).map(|t| m.create(&template_tokens(t)).0).collect();
    let mut forks = Vec::with_capacity(n);
    for k in 0..n {
        forks.push(m.fork(roots[k % TEMPLATES]));
    }
    (m, roots, forks)
}

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("sessions", smoke);

    println!("=== refcounted session sharing over forked prefix templates ===");
    println!(
        "=== {TEMPLATES} templates x {TEMPLATE_BLOCKS} blocks x {BLOCK_TOKENS} tokens ==="
    );

    for &n in sweep(smoke) {
        let t0 = std::time::Instant::now();
        let (m, roots, forks) = populate(n);
        let built = t0.elapsed();

        let sessions = (n + TEMPLATES) as u64;
        let unique = (TEMPLATES * TEMPLATE_BLOCKS) as u64;
        let refs = m.refs();
        assert_eq!(m.live_sessions(), sessions);
        assert_eq!(refs.unique_blocks(), unique, "forks must add zero blocks");
        assert_eq!(refs.total_refs(), sessions * TEMPLATE_BLOCKS as u64);
        assert_eq!(refs.shared_blocks(), unique, "every template block is shared");
        let hist = refs.histogram();
        assert_eq!(hist[REFCOUNT_BUCKETS - 1], unique, "all blocks sit at refcount 8+");

        let est = m.mem_footprint();
        let per_session = est.total() / sessions;
        println!(
            "n={n:<9} sessions {sessions:>9}  blocks {unique:>3}  refs {:>9}  \
             metadata {:>11} B ({per_session} B/session)  built in {built:.2?}",
            refs.total_refs(),
            est.total(),
        );
        assert!(
            per_session < 256,
            "a forked session must cost well under 256 B, got {per_session}"
        );

        // Hand-predictable counters: gated exactly by the committed
        // baseline.
        art.counter(&format!("s{n}.logical_sessions"), sessions);
        art.counter(&format!("s{n}.unique_blocks"), unique);
        art.counter(&format!("s{n}.total_refs"), sessions * TEMPLATE_BLOCKS as u64);
        art.counter(&format!("s{n}.shared_blocks"), unique);
        art.counter(&format!("s{n}.hist_top_bucket"), hist[REFCOUNT_BUCKETS - 1]);
        // Layout-dependent: deterministic per binary, absent from the
        // baseline.
        art.counter(&format!("s{n}.metadata_bytes"), est.total());
        art.timing_ns(&format!("s{n}.populate_ns"), built.as_nanos() as u64);

        // Tear the whole population down: every reference must come back
        // exactly once, leaving the table empty.
        let t0 = std::time::Instant::now();
        for id in forks {
            m.drop_session(id);
        }
        for id in roots {
            m.drop_session(id);
        }
        let dropped = t0.elapsed();
        assert_eq!(refs.total_refs(), 0, "drops must release every reference");
        assert_eq!(refs.unique_blocks(), 0);
        assert_eq!(m.live_sessions(), 0);
        art.counter(&format!("s{n}.refs_after_drop"), refs.total_refs());
        art.counter(&format!("s{n}.unique_after_drop"), refs.unique_blocks());
        art.timing_ns(&format!("s{n}.teardown_ns"), dropped.as_nanos() as u64);
    }

    println!("\n=== wall-clock: session ops and the Zipfian trace generator ===");
    let iters = if smoke { 2_000 } else { 20_000 };
    let (m, roots, _forks) = populate(10_000);
    let fork_drop = Bencher::new("session fork+drop roundtrip")
        .fixed_iters(iters)
        .batch(64)
        .run(|| {
            let child = m.fork(roots[0]);
            m.drop_session(child);
        });
    println!("{}", fork_drop.report());
    art.push(&fork_drop);

    let snapshot = Bencher::new("session snapshot rollup").fixed_iters(iters / 4).run(|| {
        let snap = m.snapshot();
        assert!(snap.live > 0);
    });
    println!("{}", snapshot.report());
    art.push(&snapshot);

    let arrivals = if smoke { 4_096 } else { 65_536 };
    let cfg = SessionWorkloadConfig::default();
    let gen = Bencher::new(format!("session trace generate n={arrivals}"))
        .fixed_iters(if smoke { 8 } else { 32 })
        .run(|| {
            let trace = generate_sessions(&cfg, arrivals);
            assert_eq!(trace.arrivals, arrivals);
        });
    println!("{}", gen.report());
    art.push(&gen);
    // Seeded and deterministic run-over-run (gated by the run1-vs-run2
    // diff), but not hand-computable — kept out of the committed
    // baseline.
    let trace = generate_sessions(&cfg, arrivals);
    art.counter(&format!("trace{arrivals}.ops"), trace.ops.len() as u64);
    art.counter(&format!("trace{arrivals}.arrivals"), trace.arrivals as u64);

    let path = art.write().expect("write BENCH_sessions.json");
    println!("wrote {}", path.display());
}
