//! Hot-path micro-benchmarks (the §Perf working set): hashing, chunking,
//! quantization codecs, the radix index, wire codecs, store ops, and the
//! in-proc protocol round-trip.  Used to drive the L3 optimization loop —
//! before/after numbers live in EXPERIMENTS.md §Perf and the machine-
//! readable trajectory in `BENCH_hotpath.json` (see docs/METRICS.md
//! "Bench artifacts").
//!
//! Iteration counts are fixed per mode (`--smoke` = CI-sized), so the
//! artifact's deterministic namespace is byte-identical run-over-run.

use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::kvc::block::{block_hashes, BlockHash};
use skymemory::kvc::chunk::{split_chunks, ChunkKey};
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::kvc::hash::sha256;
use skymemory::kvc::manager::{KvcConfig, KvcManager};
use skymemory::kvc::quantize::Quantizer;
use skymemory::kvc::radix::RadixTree;
use skymemory::net::messages::{decode_request, encode_request, Envelope, Request};
use skymemory::net::transport::{GroundView, InProcTransport};
use skymemory::satellite::fleet::Fleet;
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};
use skymemory::util::rng::XorShift64;
use std::sync::Arc;

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("hotpath", smoke);
    let mut rng = XorShift64::new(1);
    // (smoke, full) measured iteration counts per group
    let pick = |s: usize, f: usize| if smoke { s } else { f };

    // --- hashing ---------------------------------------------------------
    let payload_64k = vec![0xA5u8; 65536];
    let r = Bencher::new("sha256 64 KiB")
        .fixed_iters(pick(256, 4096))
        .bytes_per_iter(65536)
        .run(|| {
            std::hint::black_box(sha256(&payload_64k));
        });
    println!("{}", r.report());
    println!("{}", r.throughput());
    art.push(&r);
    let tokens: Vec<i32> = (0..256).collect();
    let r = Bencher::new("block_hashes 256 tokens / 32-blocks")
        .fixed_iters(pick(256, 4096))
        .run(|| {
            std::hint::black_box(block_hashes(&tokens, 32));
        });
    println!("{}", r.report());
    art.push(&r);

    // --- quantization (the KVC encode/decode on the request path) --------
    let kv: Vec<f32> = (0..65536).map(|_| (rng.next_f64() as f32 - 0.5) * 4.0).collect();
    for q in [
        Quantizer::F32,
        Quantizer::QuantoInt8 { group: 32 },
        Quantizer::HqqInt8 { group: 32 },
    ] {
        let enc = q.encode(&kv);
        let r = Bencher::new(format!("{}::encode 64k f32 (one block)", q.name()))
            .fixed_iters(pick(64, 512))
            .bytes_per_iter(kv.len() * 4)
            .run(|| {
                std::hint::black_box(q.encode(&kv));
            });
        println!("{}", r.report());
        println!("{}", r.throughput());
        art.push(&r);
        let r = Bencher::new(format!("{}::decode", q.name()))
            .fixed_iters(pick(64, 512))
            .bytes_per_iter(kv.len() * 4)
            .run(|| {
                std::hint::black_box(q.decode(&enc).unwrap());
            });
        println!("{}", r.report());
        println!("{}", r.throughput());
        art.push(&r);
    }

    // --- chunking ---------------------------------------------------------
    let payload = vec![0u8; 73728];
    let r = Bencher::new("split_chunks 72 KiB / 6 kB")
        .fixed_iters(pick(512, 8192))
        .bytes_per_iter(73728)
        .run(|| {
            std::hint::black_box(split_chunks(&payload, 6000));
        });
    println!("{}", r.report());
    art.push(&r);

    // --- radix index -------------------------------------------------------
    let mut tree = RadixTree::new();
    let mut keys = Vec::new();
    for i in 0..10_000u32 {
        let mut key = vec![0u8; 32 * 4];
        for (j, b) in key.iter_mut().enumerate() {
            *b = (i as usize * 31 + j) as u8;
        }
        tree.insert(&key, i);
        keys.push(key);
    }
    let r = Bencher::new("radix::longest_prefix (10k keys)")
        .fixed_iters(pick(8192, 131_072))
        .batch(64)
        .run(|| {
            std::hint::black_box(tree.longest_prefix(&keys[4321]));
        });
    println!("{}", r.report());
    art.push(&r);

    // --- wire codecs -------------------------------------------------------
    let env = Envelope::new(SatId::new(3, 14), 42);
    let req = Request::Set {
        key: ChunkKey::new(BlockHash([7; 32]), 3),
        payload: vec![0xCD; 6000],
    };
    let bytes = encode_request(&env, &req);
    let r = Bencher::new("messages::encode Set(6 kB)")
        .fixed_iters(pick(2048, 32768))
        .batch(8)
        .bytes_per_iter(bytes.len())
        .run(|| {
            std::hint::black_box(encode_request(&env, &req));
        });
    println!("{}", r.report());
    art.push(&r);
    let r = Bencher::new("messages::decode Set(6 kB)")
        .fixed_iters(pick(2048, 32768))
        .batch(8)
        .bytes_per_iter(bytes.len())
        .run(|| {
            std::hint::black_box(decode_request(&bytes).unwrap());
        });
    println!("{}", r.report());
    art.push(&r);

    // --- full protocol round trip (in-proc, no link emulation) ------------
    let torus = Torus::new(15, 15);
    let fleet = Arc::new(Fleet::new(torus, 1 << 30, EvictionPolicy::Gossip));
    let center = SatId::new(7, 7);
    let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
    let transport = Arc::new(InProcTransport::new(fleet, ground, None));
    let manager = KvcManager::new(
        KvcConfig { n_servers: 10, ..KvcConfig::default() },
        torus,
        transport,
    );
    let hashes = block_hashes(&tokens, 32);
    let kv_block: Vec<f32> = kv[..65536].to_vec();
    manager.put_block(&hashes, 0, &kv_block, 0).unwrap();
    let r = Bencher::new("manager::put_block 64k f32 (13 chunks)")
        .fixed_iters(pick(32, 256))
        .bytes_per_iter(kv_block.len() * 4)
        .run(|| {
            // fresh hash each iter so the index does not dedupe
            let mut t2 = tokens.clone();
            t2[0] = rng.next_u64() as i32;
            let h = block_hashes(&t2, 32);
            manager.put_block(&h, 0, &kv_block, 0).unwrap();
        });
    println!("{}", r.report());
    art.push(&r);
    let r = Bencher::new("manager::fetch_block 64k f32 (13 chunks)")
        .fixed_iters(pick(64, 512))
        .bytes_per_iter(kv_block.len() * 4)
        .run(|| {
            std::hint::black_box(manager.fetch_block(&hashes, 0, 0).unwrap().unwrap());
        });
    println!("{}", r.report());
    art.push(&r);
    println!(
        "  (per-fetch payload {} bytes quantized)",
        manager.config.quantizer.encoded_len(kv_block.len())
    );

    // Manager/scheduler counters: deterministic given the fixed iteration
    // counts and the seeded rng (warmup runs max(1, n/8) extra iters).
    let kvc = manager.stats.snapshot();
    art.counter("manager.blocks_stored", kvc.blocks_stored);
    art.counter("manager.chunks_stored", kvc.chunks_stored);
    art.counter("manager.blocks_fetched", kvc.blocks_fetched);
    art.counter("manager.chunks_fetched", kvc.chunks_fetched);
    art.counter("manager.bytes_stored", kvc.bytes_stored);
    art.counter("manager.bytes_fetched", kvc.bytes_fetched);
    art.counter("manager.broken_blocks", kvc.broken_blocks);
    let sched = manager.sched().stats.snapshot();
    art.counter("sched.batches", sched.batches);
    art.counter("sched.transfers", sched.transfers);
    art.counter("sched.failed_transfers", sched.failed_transfers);
    let path = art.write().expect("write BENCH_hotpath.json");
    println!("wrote {}", path.display());
}
