//! Bench + reproduction harness for Figures 13/14/15: the three
//! chunk-to-server mapping layouts (printed exactly as the paper's grids)
//! and the cost of layout generation + migration planning.
//!
//! Writes `BENCH_mapping_layouts.json`: iteration/shape counters in the
//! deterministic namespace, wall-clock stats in timing.

use skymemory::constellation::topology::{SatId, Torus};
use skymemory::mapping::{migration, Strategy};
use skymemory::util::bench::{smoke_mode, BenchArtifact, Bencher};

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("mapping_layouts", smoke);
    let pick = |s: usize, f: usize| if smoke { s } else { f };

    println!("=== Figure 13 (rotation-aware row-major) ===");
    print!("{}", skymemory::repro::fig13());
    println!("=== Figure 14 (hop-aware concentric rings) ===");
    print!("{}", skymemory::repro::fig14());
    println!("=== Figure 15 (rotation-and-hop-aware bounded rings) ===");
    print!("{}", skymemory::repro::fig15());

    println!("=== timings ===");
    let torus = Torus::new(15, 15);
    let center = SatId::new(7, 7);
    art.counter("strategies", Strategy::ALL.len() as u64);
    art.counter("torus_sats", torus.len() as u64);
    for st in Strategy::ALL {
        for n in [9usize, 81] {
            let layout = st.initial_layout(&torus, center, n);
            assert_eq!(layout.len(), n);
            let r = Bencher::new(format!("{}::layout n={n}", st.name()))
                .fixed_iters(pick(256, 2048))
                .batch(if n == 9 { 16 } else { 4 })
                .run(|| {
                    std::hint::black_box(st.initial_layout(&torus, center, n));
                });
            println!("{}", r.report());
            art.push(&r);
        }
    }
    let r = Bencher::new("layout_at with 7 epochs of migration (81)")
        .fixed_iters(pick(64, 512))
        .run(|| {
            std::hint::black_box(Strategy::RotationHopAware.layout_at(&torus, center, 81, 7));
        });
    println!("{}", r.report());
    art.push(&r);
    let plan = migration::migration_plan(&torus, Strategy::RotationHopAware, center, 81, 0);
    art.counter("migration_plan_moves", plan.len() as u64);
    let r = Bencher::new("migration_plan (81 servers)").fixed_iters(pick(64, 512)).run(|| {
        std::hint::black_box(migration::migration_plan(
            &torus,
            Strategy::RotationHopAware,
            center,
            81,
            0,
        ));
    });
    println!("{}", r.report());
    art.push(&r);

    let path = art.write().expect("write BENCH_mapping_layouts.json");
    println!("wrote {}", path.display());
}
