//! Bench + reproduction harness for Figures 13/14/15: the three
//! chunk-to-server mapping layouts (printed exactly as the paper's grids)
//! and the cost of layout generation + migration planning.

use skymemory::constellation::topology::{SatId, Torus};
use skymemory::mapping::{migration, Strategy};
use skymemory::util::bench::Bencher;

fn main() {
    println!("=== Figure 13 (rotation-aware row-major) ===");
    print!("{}", skymemory::repro::fig13());
    println!("=== Figure 14 (hop-aware concentric rings) ===");
    print!("{}", skymemory::repro::fig14());
    println!("=== Figure 15 (rotation-and-hop-aware bounded rings) ===");
    print!("{}", skymemory::repro::fig15());

    println!("=== timings ===");
    let torus = Torus::new(15, 15);
    let center = SatId::new(7, 7);
    for st in Strategy::ALL {
        for n in [9usize, 81] {
            let r = Bencher::new(format!("{}::layout n={n}", st.name())).run(|| {
                std::hint::black_box(st.initial_layout(&torus, center, n));
            });
            println!("{}", r.report());
        }
    }
    let r = Bencher::new("layout_at with 7 epochs of migration (81)").run(|| {
        std::hint::black_box(Strategy::RotationHopAware.layout_at(&torus, center, 81, 7));
    });
    println!("{}", r.report());
    let r = Bencher::new("migration_plan (81 servers)").run(|| {
        std::hint::black_box(migration::migration_plan(
            &torus,
            Strategy::RotationHopAware,
            center,
            81,
            0,
        ));
    });
    println!("{}", r.report());
}
