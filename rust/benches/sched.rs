//! Bench: the `net::sched` event engine vs the old thread-scoped chunk
//! fan-out, at equal network fidelity.
//!
//! The baseline below reimplements what `kvc::manager` used to do before
//! the rewire — stripe one block's chunks over at most 8 scoped OS
//! threads, each issuing *timed* transport requests that sleep the
//! emulated per-request round trip — and races it against
//! [`NetScheduler::run_batch`], which sleeps one *pipelined batch
//! makespan* instead.  Both sides emulate the same physical network
//! (scaled 1/20 so iterations stay fast); the difference measured is
//! exactly what the rewire buys: serial per-request round trips vs
//! event-driven pipelining over per-link windows.
//!
//! * `paper-19x5` shape: 16 chunks over 9 servers — the engine must be
//!   no slower (asserted, with slack for timer noise);
//! * `mega-shell` shape: 1152 chunks over 25 servers — the engine must
//!   be faster (asserted): a thread per chunk is unthinkable and the
//!   8-thread stripe serializes 144 round trips per worker.
//!
//! Also times one full `run_scenario` of both scenarios end to end
//! (virtual time only, no sleeping), and writes `BENCH_sched.json`:
//! scenario counters and the scheduler's virtual-time totals go into the
//! deterministic namespace (they are machine-independent), wall-clock
//! stats into the timing namespace.  Run with `--smoke` (CI) for small
//! fixed iteration counts; the speedup assertions hold in both modes.
//!
//! ```text
//! cargo bench --bench sched [-- --smoke]
//! ```

use skymemory::constellation::geometry::Geometry;
use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::kvc::block::BlockHash;
use skymemory::kvc::chunk::ChunkKey;
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::mapping::Strategy;
use skymemory::net::sched::{ChunkOp, NetScheduler, SchedConfig, Transfer};
use skymemory::net::transport::{GroundView, InProcTransport, LinkModel, Transport};
use skymemory::obs::Recorder;
use skymemory::satellite::fleet::Fleet;
use skymemory::sim::harness::run_scenario;
use skymemory::sim::scenario::ScenarioSpec;
use skymemory::util::bench::{smoke_mode, slug, BenchArtifact, Bencher};
use std::sync::Arc;
use std::time::Instant;

/// The old manager's thread cap, reproduced for the baseline.
const MAX_FANOUT: usize = 8;

/// Emulated-network time scale (1/20 of real) — large enough that the
/// sleeps dominate engine/thread machinery, small enough to iterate.
const SLEEP_SCALE: f64 = 0.05;

struct Shape {
    name: &'static str,
    planes: usize,
    sats_per_plane: usize,
    n_servers: usize,
    n_chunks: usize,
    chunk_bytes: usize,
    bandwidth_bps: f64,
    /// Engine-vs-baseline wall-clock floor asserted for this shape.
    min_speedup: f64,
    /// Fixed measured iterations (smoke, full).
    iters: (usize, usize),
}

const SHAPES: [Shape; 2] = [
    Shape {
        name: "paper-19x5",
        planes: 5,
        sats_per_plane: 19,
        n_servers: 9,
        n_chunks: 16,
        chunk_bytes: 600,
        bandwidth_bps: 1e9,
        // acceptance: "no slower" — 0.9 leaves room for timer noise
        min_speedup: 0.9,
        iters: (12, 60),
    },
    Shape {
        name: "mega-shell",
        planes: 72,
        sats_per_plane: 22,
        n_servers: 25,
        n_chunks: 1152,
        chunk_bytes: 50,
        bandwidth_bps: 2e7,
        // acceptance: "faster" — pipelining beats 144 serial RTTs/worker
        min_speedup: 1.0,
        iters: (1, 4),
    },
];

struct Stack {
    layout: Vec<SatId>,
    inproc: Arc<InProcTransport>,
}

fn build(shape: &Shape, sleep_scale: f64) -> Stack {
    let torus = Torus::new(shape.planes, shape.sats_per_plane);
    let geometry = Geometry::new(550.0, shape.sats_per_plane, shape.planes);
    let center = SatId::new((shape.planes / 2) as u16, (shape.sats_per_plane / 2) as u16);
    let fleet = Arc::new(Fleet::new(torus, 64 << 20, EvictionPolicy::Lazy));
    let los = LosGrid::new(center, 2, 2.min(shape.planes / 2));
    let ground = GroundView::new(center, &los, torus.sats_per_plane);
    let mut link = LinkModel::laser_defaults(geometry);
    link.bandwidth_bps = shape.bandwidth_bps;
    link.sleep_scale = sleep_scale;
    let inproc = Arc::new(InProcTransport::new(fleet, ground, Some(link)));
    let layout = Strategy::RotationHopAware.initial_layout(&torus, center, shape.n_servers);
    Stack { layout, inproc }
}

fn chunk_key(i: usize) -> ChunkKey {
    ChunkKey::new(BlockHash([0xB1; 32]), i as u32)
}

/// The pre-rewire fan-out: stripe one block's Set pass, then its Get
/// pass, over scoped OS threads (exactly the old manager's shape); every
/// request sleeps its own emulated round trip.
fn threaded_block(stack: &Stack, shape: &Shape) {
    let n_workers = shape.n_chunks.min(MAX_FANOUT).max(1);
    std::thread::scope(|scope| {
        for w in 0..n_workers {
            let layout = &stack.layout;
            let transport = &stack.inproc;
            scope.spawn(move || {
                let mut i = w;
                while i < shape.n_chunks {
                    let dest = layout[i % shape.n_servers];
                    transport
                        .set_chunk(dest, chunk_key(i), vec![0xAB; shape.chunk_bytes])
                        .unwrap();
                    i += n_workers;
                }
            });
        }
    });
    std::thread::scope(|scope| {
        for w in 0..n_workers {
            let layout = &stack.layout;
            let transport = &stack.inproc;
            scope.spawn(move || {
                let mut i = w;
                while i < shape.n_chunks {
                    let dest = layout[i % shape.n_servers];
                    let _ = transport.get_chunk(dest, chunk_key(i)).unwrap();
                    i += n_workers;
                }
            });
        }
    });
}

/// The same block through the event engine: one Set batch, one Get
/// batch, each sleeping its pipelined makespan once.
fn sched_block(sched: &NetScheduler, stack: &Stack, shape: &Shape) {
    let sets: Vec<Transfer> = (0..shape.n_chunks)
        .map(|i| Transfer {
            tag: i as u64,
            op: ChunkOp::Set {
                dest: stack.layout[i % shape.n_servers],
                key: chunk_key(i),
                data: vec![0xAB; shape.chunk_bytes],
            },
        })
        .collect();
    let report = sched.run_batch(sets);
    assert_eq!(report.outcomes.len(), shape.n_chunks);
    let gets: Vec<Transfer> = (0..shape.n_chunks)
        .map(|i| Transfer {
            tag: i as u64,
            op: ChunkOp::Get { dest: stack.layout[i % shape.n_servers], key: chunk_key(i) },
        })
        .collect();
    let report = sched.run_batch(gets);
    assert_eq!(report.outcomes.len(), shape.n_chunks);
}

fn main() {
    let smoke = smoke_mode();
    let mut art = BenchArtifact::new("sched", smoke);

    println!("=== chunk fan-out at 1/{} emulated network time ===", (1.0 / SLEEP_SCALE) as u32);
    println!("=== thread-scoped baseline (serial RTT sleeps) vs net::sched (batch makespan) ===");
    let mut failures = 0u32;
    for shape in &SHAPES {
        let iters = if smoke { shape.iters.0 } else { shape.iters.1 };
        let stack = build(shape, SLEEP_SCALE);
        let baseline = Bencher::new(format!("{} threads(8) {} chunks", shape.name, shape.n_chunks))
            .fixed_iters(iters)
            .run(|| threaded_block(&stack, shape));
        println!("{}", baseline.report());
        art.push(&baseline);

        let stack = build(shape, SLEEP_SCALE);
        let transport: Arc<dyn Transport> = stack.inproc.clone();
        let sched = NetScheduler::new(transport, SchedConfig { window: 8 });
        let engine = Bencher::new(format!("{} sched(w=8) {} chunks", shape.name, shape.n_chunks))
            .fixed_iters(iters)
            .run(|| sched_block(&sched, &stack, shape));
        println!("{}", engine.report());
        art.push(&engine);

        // The engine's virtual time per iteration is machine-independent:
        // total virtual ns / batches run is a pure function of the shape.
        let snap = sched.stats.snapshot();
        let prefix = slug(shape.name);
        let transfers_per_iter = snap.transfers / snap.batches.max(1) * 2;
        let virtual_ns_per_iter = snap.virtual_ns / (snap.batches.max(1) / 2);
        art.counter(&format!("{prefix}.transfers_per_iter"), transfers_per_iter);
        art.counter(&format!("{prefix}.virtual_ns_per_iter"), virtual_ns_per_iter);

        let speedup = baseline.mean.as_secs_f64() / engine.mean.as_secs_f64();
        let ok = speedup >= shape.min_speedup;
        if !ok {
            failures += 1;
        }
        println!(
            "{:<16} engine is {speedup:.2}x the thread-scoped baseline (floor {:.1}x) -> {}\n",
            shape.name,
            shape.min_speedup,
            if ok { "OK" } else { "REGRESSION" }
        );
    }

    println!("=== end-to-end scenarios on the event engine (seed 42, virtual time only) ===");
    for spec in [ScenarioSpec::paper_19x5(42), ScenarioSpec::mega_shell(42)] {
        let t0 = Instant::now();
        let r = run_scenario(&spec);
        let wall = t0.elapsed();
        println!(
            "{:<16} {:>4} reqs  hit {:>5.1}%  {:>8} transfers  peak in-flight {:>5}  \
             queued {:>9.3} ms  wall {:.2?}",
            r.name,
            r.requests,
            100.0 * r.block_hit_rate,
            r.sched.transfers,
            r.sched.peak_in_flight,
            r.sched.queued_ns as f64 / 1e6,
            wall
        );
        let prefix = format!("scenario.{}", slug(&r.name));
        art.counter(&format!("{prefix}.requests"), r.requests);
        art.counter(&format!("{prefix}.hit_permille"), (r.block_hit_rate * 1000.0).round() as u64);
        art.counter(&format!("{prefix}.transfers"), r.sched.transfers);
        art.counter(&format!("{prefix}.virtual_time_ns"), r.sched.virtual_ns);
        art.counter(&format!("{prefix}.peak_in_flight"), r.sched.peak_in_flight);
        art.timing_ns(&format!("{prefix}.wall_ns"), wall.as_nanos() as u64);
    }

    println!("=== tracing overhead: NoopSink (default) vs recording sink, no network sleeps ===");
    {
        let shape = &SHAPES[0];
        let iters = if smoke { 20 } else { 120 };

        // No emulated sleeps: the fan-out machinery itself is the workload,
        // so any sink cost shows up undiluted.
        let stack = build(shape, 0.0);
        let transport: Arc<dyn Transport> = stack.inproc.clone();
        let sched = NetScheduler::new(transport, SchedConfig { window: 8 });
        let off = Bencher::new(format!("{} trace=off {} chunks", shape.name, shape.n_chunks))
            .fixed_iters(iters)
            .run(|| sched_block(&sched, &stack, shape));
        println!("{}", off.report());
        art.push(&off);

        let stack = build(shape, 0.0);
        let transport: Arc<dyn Transport> = stack.inproc.clone();
        let sched = NetScheduler::new(transport, SchedConfig { window: 8 });
        let recorder = Arc::new(Recorder::new());
        sched.set_trace_sink(recorder.clone(), 0);
        let on = Bencher::new(format!("{} trace=rec {} chunks", shape.name, shape.n_chunks))
            .fixed_iters(iters)
            .run(|| sched_block(&sched, &stack, shape));
        println!("{}", on.report());
        art.push(&on);

        // Events per sched_block call are a pure function of the shape, so
        // the counter is deterministic.  `fixed_iters(n)` also runs
        // `max(1, n/8)` warmup calls through the recorder.
        let calls = (iters + (iters / 8).max(1)) as u64;
        let events = recorder.take().len() as u64;
        assert_eq!(events % calls, 0, "trace event count must be stable per call");
        art.counter("trace.events_per_iter", events / calls);
        let overhead = on.mean.as_secs_f64() / off.mean.as_secs_f64();
        println!(
            "recording sink costs {overhead:.2}x over NoopSink ({} events/iter)\n",
            events / calls
        );
    }

    let path = art.write().expect("write BENCH_sched.json");
    println!("wrote {}", path.display());
    assert_eq!(failures, 0, "{failures} shape(s) regressed below their speedup floor");
}
