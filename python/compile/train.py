"""Build-time training of the byte-level LM (L2 training path).

Runs once from aot.py when artifacts/weights.bin is absent.  Pure JAX with
a from-scratch Adam; uses the jnp reference attention (interpret-mode
Pallas would be needlessly slow here — kernel equivalence is pinned by
python/tests/test_kernel.py and test_model.py instead).
"""

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .config import CONFIG, ModelConfig
from .corpus import corpus_bytes
from .model import init_params, loss_fn, param_spec, params_from_list, params_to_list


def _batches(data: np.ndarray, batch: int, seq: int, steps: int, seed: int):
    rng = np.random.default_rng(seed)
    n = len(data) - seq - 1
    for _ in range(steps):
        starts = rng.integers(0, n, size=batch)
        yield np.stack([data[s : s + seq + 1] for s in starts]).astype(np.int32)


def adam_update(params_flat, grads_flat, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = [b1 * mi + (1 - b1) * g for mi, g in zip(m, grads_flat)]
    v = [b2 * vi + (1 - b2) * g * g for vi, g in zip(v, grads_flat)]
    mhat = [mi / (1 - b1**step) for mi in m]
    vhat = [vi / (1 - b2**step) for vi in v]
    new = [
        p - lr * mh / (jnp.sqrt(vh) + eps)
        for p, mh, vh in zip(params_flat, mhat, vhat)
    ]
    return new, m, v


def train(
    cfg: ModelConfig = CONFIG,
    *,
    steps: int = 800,
    batch: int = 16,
    seq: int = 128,
    lr: float = 3e-3,
    seed: int = 0,
    log_every: int = 50,
):
    """Train and return (params_dict, loss_log:list[(step, loss)])."""
    data = np.frombuffer(corpus_bytes(), dtype=np.uint8)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    flat = params_to_list(params, cfg)
    m = [jnp.zeros_like(p) for p in flat]
    v = [jnp.zeros_like(p) for p in flat]

    def step_loss(flat_params, tokens):
        return loss_fn(params_from_list(flat_params, cfg), tokens, cfg=cfg)

    grad_fn = jax.jit(jax.value_and_grad(step_loss))
    log = []
    t0 = time.time()
    for i, tokens in enumerate(_batches(data, batch, seq, steps, seed), start=1):
        loss, grads = grad_fn(flat, jnp.asarray(tokens))
        # cosine decay with short warmup
        warm = min(1.0, i / 50)
        decay = 0.5 * (1 + np.cos(np.pi * i / steps))
        flat, m, v = adam_update(flat, grads, m, v, i, lr * warm * (0.1 + 0.9 * decay))
        if i % log_every == 0 or i == 1:
            log.append((i, float(loss)))
            print(f"step {i:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return params_from_list(flat, cfg), log


def save_weights(params, path, cfg: ModelConfig = CONFIG):
    """Flat little-endian f32 concat in param_spec order; returns manifest."""
    manifest = []
    offset = 0
    with open(path, "wb") as f:
        for name, shape in param_spec(cfg):
            arr = np.asarray(params[name], dtype="<f4")
            assert arr.shape == tuple(shape), (name, arr.shape, shape)
            f.write(arr.tobytes())
            manifest.append(
                {
                    "name": name,
                    "shape": list(shape),
                    "offset_bytes": offset,
                    "size_bytes": arr.nbytes,
                }
            )
            offset += arr.nbytes
    return manifest


def load_weights(path, cfg: ModelConfig = CONFIG):
    raw = np.fromfile(path, dtype="<f4")
    params, offset = {}, 0
    for name, shape in param_spec(cfg):
        n = int(np.prod(shape))
        params[name] = jnp.asarray(raw[offset : offset + n].reshape(shape))
        offset += n
    if offset != raw.size:
        raise ValueError(f"weights.bin size mismatch: {offset} != {raw.size}")
    return params


if __name__ == "__main__":
    params, log = train()
    manifest = save_weights(params, "weights.bin")
    json.dump(log, open("train_log.json", "w"))
    print("saved", sum(m["size_bytes"] for m in manifest), "bytes")
