"""L1: KV-cache causal attention as a Pallas kernel (flash-attention style).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot spot
runs on a Jetson GPU (CUDA threadblocks + shared memory).  On TPU the same
insight — stream the KV cache through fast on-chip memory in tiles while
keeping an online softmax — maps to:

  * grid over heads; one kernel instance owns one head's query block,
  * BlockSpec carves the [H, S, D] caches into per-head [S, D] VMEM views,
  * keys/values are consumed in KEY_BLOCK-sized tiles (the VMEM analogue of
    the CUDA shared-memory tile), with a running (max, denom, acc) online
    softmax so the full [B, S] score matrix never materializes,
  * matmuls are shaped [B, D] x [D, KEY_BLOCK] -> MXU-friendly.

interpret=True is mandatory here: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel is lowered to plain HLO for execution and the
TPU mapping is an estimate (EXPERIMENTS.md §Perf has the VMEM/MXU budget).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..config import KEY_BLOCK

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, pos_ref, o_ref, *, key_block):
    """One head: q [1,B,D] vs cache k/v [1,S,D], valid cols <= pos+row."""
    q = q_ref[0]  # [B, D]
    pos = pos_ref[0]
    b, d = q.shape
    s = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    row = jax.lax.broadcasted_iota(jnp.int32, (b, key_block), 0)

    n_kb = s // key_block

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_tile = pl.load(k_ref, (0, pl.ds(i * key_block, key_block), slice(None)))
        v_tile = pl.load(v_ref, (0, pl.ds(i * key_block, key_block), slice(None)))
        scores = jnp.dot(q, k_tile.T) * scale  # [B, KB]
        col = i * key_block + jax.lax.broadcasted_iota(
            jnp.int32, (b, key_block), 1
        )
        scores = jnp.where(col <= pos + row, scores, NEG_INF)
        m_cur = jnp.max(scores, axis=-1, keepdims=True)  # [B,1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new)  # [B, KB]
        alpha = jnp.exp(m_prev - m_new)  # rescale of previous accum
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = alpha * acc + jnp.dot(p, v_tile)  # [B, D]
        return m_new, l_new, acc

    m0 = jnp.full((b, 1), NEG_INF, dtype=q.dtype)
    l0 = jnp.zeros((b, 1), dtype=q.dtype)
    acc0 = jnp.zeros((b, d), dtype=q.dtype)
    _, l, acc = jax.lax.fori_loop(0, n_kb, body, (m0, l0, acc0))
    o_ref[0] = acc / l


def mha_with_cache(q, k, v, pos, *, key_block=KEY_BLOCK, interpret=True):
    """Pallas multi-head attention of a new block against a KV cache.

    Args / returns match kernels.ref.mha_with_cache_ref:
      q [H,B,D], k/v [H,S,D], pos scalar int32 -> [H,B,D].
    Requires S % key_block == 0.
    """
    h, b, d = q.shape
    s = k.shape[1]
    if s % key_block != 0:
        raise ValueError(f"cache length {s} not a multiple of {key_block}")
    pos_arr = jnp.reshape(pos, (1,)).astype(jnp.int32)
    kernel = functools.partial(_attn_kernel, key_block=key_block)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),  # q: one head
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # k cache: one head
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),  # v cache: one head
            pl.BlockSpec((1,), lambda i: (0,)),  # pos scalar
        ],
        out_specs=pl.BlockSpec((1, b, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, b, d), q.dtype),
        interpret=interpret,
    )(q, k, v, pos_arr)
