"""Pure-jnp oracle for the KV-cache causal attention kernel.

This is the correctness reference the Pallas kernel (attention.py) is
checked against in python/tests/test_kernel.py, and the implementation the
training path uses (interpret-mode Pallas is too slow for the train loop).
"""

import jax.numpy as jnp

NEG_INF = -1e30


def mha_with_cache_ref(q, k, v, pos):
    """Multi-head attention of a new token block against a KV cache.

    Args:
      q:   [H, B, D] queries for the B new tokens (one block).
      k:   [H, S, D] key cache; positions [pos, pos+B) already hold the new
           block's keys, positions >= pos+B are garbage and must be masked.
      v:   [H, S, D] value cache, same layout.
      pos: scalar int32, number of tokens already in the cache before this
           block (the new block occupies [pos, pos+B)).

    Returns:
      [H, B, D] attention outputs.

    Query i (absolute position pos+i) may attend to cache positions
    j <= pos + i  (causal within the block, everything before it).
    """
    h, b, d = q.shape
    s = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("hbd,hsd->hbs", q, k) * scale
    row = jnp.arange(b, dtype=jnp.int32)[:, None]  # query index in block
    col = jnp.arange(s, dtype=jnp.int32)[None, :]  # cache position
    mask = col <= (pos + row)  # [B, S]
    scores = jnp.where(mask[None, :, :], scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hbs,hsd->hbd", p, v)


def causal_attention_ref(q, k, v):
    """Plain batched causal self-attention (training path, no cache).

    q, k, v: [N, H, T, D] -> [N, H, T, D]
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = jnp.einsum("nhtd,nhsd->nhts", q, k) * scale
    t = q.shape[2]
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("nhts,nhsd->nhtd", p, v)
