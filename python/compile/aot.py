"""AOT compile path: train (once) -> lower prefill/decode -> artifacts/.

Emits HLO *text* (NOT lowered.compile()/serialize()): jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust `xla` crate's
xla_extension 0.5.1 rejects; the HLO text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs (all consumed by rust/src/runtime/):
  artifacts/prefill.hlo.txt   forward of one BLOCK_TOKENS block vs cache
  artifacts/decode.hlo.txt    forward of one token vs cache
  artifacts/weights.bin       flat <f4 params in param_spec order
  artifacts/model_config.json config + weights manifest + arg-order contract
  artifacts/train_log.json    build-time loss curve

Run: cd python && python -m compile.aot --outdir ../artifacts
Python never runs again after this: the rust binary is self-contained.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .config import CONFIG, KEY_BLOCK
from .model import make_serving_fn, serving_arg_specs
from .train import load_weights, save_weights, train


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_serving(block: int) -> str:
    fn = make_serving_fn(CONFIG, block=block, use_pallas=True)
    specs = serving_arg_specs(CONFIG, block)
    return to_hlo_text(jax.jit(fn).lower(*specs))


def _inputs_digest() -> str:
    """Digest of the compile-path sources, to skip rebuilds when unchanged."""
    h = hashlib.sha256()
    base = os.path.dirname(__file__)
    names = ["config.py", "model.py", "train.py", "corpus.py", "aot.py",
             os.path.join("kernels", "attention.py"),
             os.path.join("kernels", "ref.py")]
    for n in names:
        with open(os.path.join(base, n), "rb") as f:
            h.update(f.read())
    return h.hexdigest()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--steps", type=int, default=800)
    args = ap.parse_args()
    out = args.outdir
    os.makedirs(out, exist_ok=True)

    digest = _inputs_digest()
    stamp = os.path.join(out, "inputs.sha256")
    done = all(
        os.path.exists(os.path.join(out, f))
        for f in ["prefill.hlo.txt", "decode.hlo.txt", "weights.bin", "model_config.json"]
    )
    if done and not args.retrain and os.path.exists(stamp) and open(stamp).read() == digest:
        print("artifacts up to date; skipping (use --retrain to force)")
        return 0

    wpath = os.path.join(out, "weights.bin")
    if os.path.exists(wpath) and not args.retrain:
        print("loading existing weights.bin")
        params = load_weights(wpath)
        manifest = save_weights(params, wpath)  # re-derive manifest
        log = []
    else:
        print(f"training byte-LM for {args.steps} steps ...")
        params, log = train(steps=args.steps)
        manifest = save_weights(params, wpath)
        json.dump(log, open(os.path.join(out, "train_log.json"), "w"))

    for name, block in [("prefill", CONFIG.block_tokens), ("decode", 1)]:
        text = lower_serving(block)
        path = os.path.join(out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    config = {
        "model": CONFIG.to_json_dict(),
        "key_block": KEY_BLOCK,
        "weights": manifest,
        # Contract with rust/src/runtime: positional PJRT args are the
        # weights in manifest order, then tokens i32[block], k_cache and
        # v_cache f32[L,H,S,D], then pos i32[].  Output is a 3-tuple
        # (logits f32[block,vocab], k_new f32[L,H,block,D], v_new likewise).
        "arg_order": ["weights..."] + ["tokens", "k_cache", "v_cache", "pos"],
        "artifacts": {"prefill": "prefill.hlo.txt", "decode": "decode.hlo.txt"},
    }
    with open(os.path.join(out, "model_config.json"), "w") as f:
        json.dump(config, f, indent=2)
    with open(stamp, "w") as f:
        f.write(digest)
    print("aot done")
    return 0


if __name__ == "__main__":
    sys.exit(main())
