"""Model + serving configuration shared between the build path (L1/L2) and
the rust coordinator (L3), which reads the JSON emitted by aot.py.

The paper's testbed model is TinyLlama-1.1B with 128-token blocks (~2.9 MB
of KV per block after 8-bit quantization).  We scale the model to a
byte-level GPT that trains at build time on CPU; the block/chunk arithmetic
of the SkyMemory protocol is preserved (a block's KVC is a fixed-size byte
string split into fixed-size chunks striped over satellites).
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 256  # byte-level tokenizer
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    max_seq: int = 256  # KV cache capacity (positions)
    block_tokens: int = 32  # SkyMemory block size in tokens (paper: 128)

    @property
    def kv_block_bytes(self) -> int:
        """f32 bytes of one block's (K, V) = 2 * L * H * block * head_dim * 4."""
        return 2 * self.n_layers * self.n_heads * self.block_tokens * self.head_dim * 4

    def to_json_dict(self):
        d = asdict(self)
        d["kv_block_bytes"] = self.kv_block_bytes
        return d


CONFIG = ModelConfig()

# Pallas kernel tiling: keys are streamed through VMEM in KEY_BLOCK-sized
# tiles (flash-attention style online softmax).  256 = one tile at the
# default max_seq: measured ~15% faster decode on the CPU-interpret path
# (EXPERIMENTS.md §Perf) and still a comfortable 32 KiB/head VMEM tile on
# TPU; contexts beyond 256 re-engage the online-softmax loop.
KEY_BLOCK = 256
