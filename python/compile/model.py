"""L2: byte-level GPT decoder with an explicit KV cache, in JAX.

Two entry points are AOT-lowered (aot.py) and executed by the rust runtime:

  * prefill — forward one BLOCK_TOKENS token block against the cache,
  * decode  — forward a single token against the cache.

Both take the KV caches as explicit arguments and return only the *new*
block's K/V ([L, H, B, D]) next to the logits: the rust coordinator owns the
cache layout (it must hold the bytes anyway to chunk them into the
SkyMemory constellation), so the multi-MB caches are never copied back.

A third, training-only forward (`forward_train`) runs full-sequence causal
attention with the pure-jnp reference kernel; train.py uses it at build
time.  The serving forwards call the Pallas kernel (kernels.attention) so
it lowers into the AOT HLO.
"""

import functools

import jax
import jax.numpy as jnp

from .config import CONFIG, ModelConfig
from .kernels.attention import mha_with_cache
from .kernels.ref import causal_attention_ref, mha_with_cache_ref

# ---------------------------------------------------------------------------
# Parameters.  Order matters: the rust runtime feeds weights.bin slices as
# positional PJRT arguments in exactly this order (see aot.py manifest).
# ---------------------------------------------------------------------------


def param_spec(cfg: ModelConfig):
    """Ordered [(name, shape)] for every learnable tensor."""
    spec = [
        ("wte", (cfg.vocab, cfg.d_model)),
        ("wpe", (cfg.max_seq, cfg.d_model)),
    ]
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        spec += [
            (p + "ln1.g", (cfg.d_model,)),
            (p + "ln1.b", (cfg.d_model,)),
            (p + "attn.wq", (cfg.d_model, cfg.d_model)),
            (p + "attn.wk", (cfg.d_model, cfg.d_model)),
            (p + "attn.wv", (cfg.d_model, cfg.d_model)),
            (p + "attn.wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2.g", (cfg.d_model,)),
            (p + "ln2.b", (cfg.d_model,)),
            (p + "mlp.w1", (cfg.d_model, cfg.d_ff)),
            (p + "mlp.b1", (cfg.d_ff,)),
            (p + "mlp.w2", (cfg.d_ff, cfg.d_model)),
            (p + "mlp.b2", (cfg.d_model,)),
        ]
    spec += [("ln_f.g", (cfg.d_model,)), ("ln_f.b", (cfg.d_model,))]
    return spec


def init_params(key, cfg: ModelConfig = CONFIG):
    """GPT-2-style init; returns a dict keyed by param_spec names."""
    params = {}
    n_residual = 2 * cfg.n_layers
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".b1", ".b2")) and len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            std = 0.02
            if name.endswith(("attn.wo", "mlp.w2")):
                # residual-branch scaling a la GPT-2
                std = 0.02 / (n_residual ** 0.5)
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


def params_to_list(params, cfg: ModelConfig = CONFIG):
    return [params[name] for name, _ in param_spec(cfg)]


def params_from_list(flat, cfg: ModelConfig = CONFIG):
    return {name: t for (name, _), t in zip(param_spec(cfg), flat)}


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _gelu(x):
    return jax.nn.gelu(x, approximate=True)


def _split_heads(x, cfg):
    # [T, d_model] -> [H, T, D]
    t = x.shape[0]
    return x.reshape(t, cfg.n_heads, cfg.head_dim).transpose(1, 0, 2)


def _merge_heads(x, cfg):
    # [H, T, D] -> [T, d_model]
    return x.transpose(1, 0, 2).reshape(x.shape[1], cfg.d_model)


# ---------------------------------------------------------------------------
# Serving forward: one block (or one token) against the KV cache
# ---------------------------------------------------------------------------


def forward_block(params, tokens, k_cache, v_cache, pos, *, cfg: ModelConfig = CONFIG, use_pallas=True):
    """Forward `tokens` (shape [B] int32) through the model with a cache.

    k_cache/v_cache: [L, H, S, D] with positions < pos valid.
    pos: scalar int32 — tokens already cached; the new block occupies
         [pos, pos+B).

    Returns (logits [B, vocab], k_new [L, H, B, D], v_new [L, H, B, D]).
    The caller is responsible for writing k_new/v_new into its cache copy.
    """
    b = tokens.shape[0]
    pos = pos.astype(jnp.int32) if hasattr(pos, "astype") else jnp.int32(pos)
    x = params["wte"][tokens]  # [B, d]
    x = x + jax.lax.dynamic_slice(params["wpe"], (pos, 0), (b, cfg.d_model))

    attend = mha_with_cache if use_pallas else mha_with_cache_ref

    k_news, v_news = [], []
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h = _layernorm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        q = _split_heads(h @ params[p + "attn.wq"], cfg)  # [H, B, D]
        k_new = _split_heads(h @ params[p + "attn.wk"], cfg)
        v_new = _split_heads(h @ params[p + "attn.wv"], cfg)
        # Write the new block into this layer's cache view before attending.
        kc = jax.lax.dynamic_update_slice(k_cache[l], k_new, (0, pos, 0))
        vc = jax.lax.dynamic_update_slice(v_cache[l], v_new, (0, pos, 0))
        o = attend(q, kc, vc, pos)  # [H, B, D]
        x = x + _merge_heads(o, cfg) @ params[p + "attn.wo"]
        h2 = _layernorm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        x = x + _gelu(h2 @ params[p + "mlp.w1"] + params[p + "mlp.b1"]) @ params[
            p + "mlp.w2"
        ] + params[p + "mlp.b2"]
        k_news.append(k_new)
        v_news.append(v_new)

    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    logits = x @ params["wte"].T  # weight-tied LM head, [B, vocab]
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def make_serving_fn(cfg: ModelConfig = CONFIG, *, block: int, use_pallas=True):
    """A lowering-ready fn(flat_params, tokens, k_cache, v_cache, pos)."""

    def fn(flat_params, tokens, k_cache, v_cache, pos):
        params = params_from_list(flat_params, cfg)
        return forward_block(
            params, tokens, k_cache, v_cache, pos, cfg=cfg, use_pallas=use_pallas
        )

    return fn


def serving_arg_specs(cfg: ModelConfig, block: int):
    """ShapeDtypeStructs matching make_serving_fn's signature."""
    f32, i32 = jnp.float32, jnp.int32
    flat = tuple(
        jax.ShapeDtypeStruct(shape, f32) for _, shape in param_spec(cfg)
    )
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, cfg.n_heads, cfg.max_seq, cfg.head_dim), f32
    )
    return (
        flat,
        jax.ShapeDtypeStruct((block,), i32),
        cache,
        cache,
        jax.ShapeDtypeStruct((), i32),
    )


# ---------------------------------------------------------------------------
# Training forward (build-time only)
# ---------------------------------------------------------------------------


def forward_train(params, tokens, *, cfg: ModelConfig = CONFIG):
    """Full-sequence causal forward.  tokens [N, T] -> logits [N, T, vocab]."""
    n, t = tokens.shape
    x = params["wte"][tokens] + params["wpe"][:t][None]  # [N, T, d]

    def split(x_):  # [N, T, d] -> [N, H, T, D]
        return x_.reshape(n, t, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)

    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h = _layernorm(x, params[p + "ln1.g"], params[p + "ln1.b"])
        q, k, v = (
            split(h @ params[p + "attn.wq"]),
            split(h @ params[p + "attn.wk"]),
            split(h @ params[p + "attn.wv"]),
        )
        o = causal_attention_ref(q, k, v)  # [N, H, T, D]
        o = o.transpose(0, 2, 1, 3).reshape(n, t, cfg.d_model)
        x = x + o @ params[p + "attn.wo"]
        h2 = _layernorm(x, params[p + "ln2.g"], params[p + "ln2.b"])
        x = x + _gelu(h2 @ params[p + "mlp.w1"] + params[p + "mlp.b1"]) @ params[
            p + "mlp.w2"
        ] + params[p + "mlp.b2"]

    x = _layernorm(x, params["ln_f.g"], params["ln_f.b"])
    return x @ params["wte"].T


def loss_fn(params, tokens, *, cfg: ModelConfig = CONFIG):
    """Next-token cross entropy.  tokens [N, T+1]."""
    logits = forward_train(params, tokens[:, :-1], cfg=cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)
