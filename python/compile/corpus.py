"""Tiny build-time training corpus for the byte-level LM.

The serving demo does not need a capable model — it needs a *real* one: a
model whose generations are drawn from a learned distribution so the
end-to-end example exercises tokenize → prefill → decode with meaningful
logits.  A few KB of thematic prose, sampled in random windows, is enough
for a 0.8M-parameter byte LM to learn word shapes and local structure.
"""

_BASE = """
The satellite passes overhead every ninety minutes, and the cache moves
with it. A constellation in low earth orbit is a ring of memory that the
planet spins beneath: each node holds a shard of the key value cache, and
each inter satellite laser link carries chunks of attention state from one
orbital plane to the next. When a prompt arrives, the model does not start
from nothing. It asks the sky what it has seen before.

A transformer reads a prompt as a sequence of tokens, and for every token
it stores a key and a value in every layer and every head. The cost of
recomputing that state grows with the square of the context, so the state
itself becomes the thing worth shipping. Split the prompt into blocks,
hash each block with the hash of the block before it, and the prefix of a
conversation becomes an address. The address names the blocks, the blocks
name the chunks, and the chunks are striped over the satellites in line of
sight.

The ground station sees ten or twenty satellites at once. The nearest one
is the center of the map, and the others are rings around it: one hop
north, one hop east, one hop south, one hop west, then the diagonals, then
the rings beyond. A chunk stored one hop away costs a few milliseconds of
light. A chunk stored across the constellation costs the worst case
distance of the torus, which is why the mapping matters: rotation aware,
hop aware, or both at once.

Satellites do not wait. Every few minutes a column of the grid slides over
the horizon and a new column rises in the west. The cache migrates ahead
of the motion: the chunks on the setting satellites are copied to the
rising ones, plane by plane, in parallel, so that when the client asks
again the answer is still one hop away. A miss is not a failure, only a
recomputation; an eviction is only a broadcast to the neighborhood. The
protocol is simple because the orbit is predictable: given the time a
block was written, every chunk location can be computed without asking
anyone.

Memory is a hierarchy and the sky is one of its levels. Registers, cache,
host memory, flash, disk, network, orbit. Each level trades latency for
capacity, and the orbit trades both for coverage: the same cache is one
hop from every point on earth. Inference begins with a lookup and ends
with a token, and between those two, light crosses the grid.
"""


def corpus_bytes() -> bytes:
    """The corpus, normalized to single-space prose."""
    text = " ".join(_BASE.split())
    # Repeat with light punctuation-variation so windows differ.
    parts = [text, text.replace(". ", ".\n"), text.lower()]
    return ("\n\n".join(parts)).encode("utf-8")
