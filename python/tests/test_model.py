"""L2 correctness: the serving forward (prefill/decode with KV cache) must
agree with the full-sequence training forward, with both attention
implementations (pallas / jnp-ref).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.config import CONFIG
from compile.model import (
    forward_block,
    forward_train,
    init_params,
    loss_fn,
    make_serving_fn,
    param_spec,
    params_from_list,
    params_to_list,
    serving_arg_specs,
)


@pytest.fixture(scope="module")
def params():
    return init_params(jax.random.PRNGKey(42))


def _empty_cache():
    c = CONFIG
    shape = (c.n_layers, c.n_heads, c.max_seq, c.head_dim)
    return jnp.zeros(shape, jnp.float32), jnp.zeros(shape, jnp.float32)


def _run_blocks(params, tokens, block, use_pallas):
    """Feed `tokens` through forward_block in `block`-sized pieces."""
    k_cache, v_cache = _empty_cache()
    logits_all = []
    for pos in range(0, len(tokens), block):
        blk = jnp.asarray(tokens[pos : pos + block], jnp.int32)
        logits, k_new, v_new = forward_block(
            params, blk, k_cache, v_cache, jnp.int32(pos), use_pallas=use_pallas
        )
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, 0, pos, 0))
        logits_all.append(logits)
    return jnp.concatenate(logits_all, axis=0), k_cache, v_cache


class TestParams:
    def test_spec_order_stable(self):
        names = [n for n, _ in param_spec(CONFIG)]
        assert names[0] == "wte" and names[1] == "wpe"
        assert names[-2:] == ["ln_f.g", "ln_f.b"]
        assert len(names) == 2 + 12 * CONFIG.n_layers + 2

    def test_roundtrip(self, params):
        flat = params_to_list(params)
        back = params_from_list(flat)
        for n in params:
            np.testing.assert_array_equal(params[n], back[n])

    def test_param_count(self, params):
        total = sum(int(np.prod(p.shape)) for p in params.values())
        # ~0.8M params for the default config
        assert 500_000 < total < 2_000_000


class TestServingVsTrain:
    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_blockwise_prefill_matches_full_forward(self, params, use_pallas):
        rng = np.random.default_rng(0)
        t = 96  # 3 blocks
        tokens = rng.integers(0, 256, size=t)
        blk_logits, _, _ = _run_blocks(params, tokens, CONFIG.block_tokens, use_pallas)
        full = forward_train(params, jnp.asarray(tokens, jnp.int32)[None])[0]
        np.testing.assert_allclose(
            np.asarray(blk_logits), np.asarray(full), rtol=2e-4, atol=2e-4
        )

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_decode_matches_prefill(self, params, use_pallas):
        """Prefill 1 block then decode token-by-token == prefill 2 blocks."""
        rng = np.random.default_rng(1)
        b = CONFIG.block_tokens
        tokens = rng.integers(0, 256, size=2 * b)
        ref_logits, _, _ = _run_blocks(params, tokens, b, use_pallas)

        k_cache, v_cache = _empty_cache()
        logits, k_new, v_new = forward_block(
            params, jnp.asarray(tokens[:b], jnp.int32), k_cache, v_cache,
            jnp.int32(0), use_pallas=use_pallas,
        )
        k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, 0, 0, 0))
        outs = []
        for i in range(b, 2 * b):
            logits, k_new, v_new = forward_block(
                params, jnp.asarray(tokens[i : i + 1], jnp.int32),
                k_cache, v_cache, jnp.int32(i), use_pallas=use_pallas,
            )
            k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (0, 0, i, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (0, 0, i, 0))
            outs.append(logits[0])
        np.testing.assert_allclose(
            np.asarray(jnp.stack(outs)),
            np.asarray(ref_logits[b:]),
            rtol=2e-4,
            atol=2e-4,
        )

    def test_kv_new_matches_cache_region(self, params):
        """Returned k_new/v_new are exactly what was written at [pos, pos+B)."""
        rng = np.random.default_rng(2)
        b = CONFIG.block_tokens
        tokens = rng.integers(0, 256, size=b)
        k_cache, v_cache = _empty_cache()
        _, k_new, v_new = forward_block(
            params, jnp.asarray(tokens, jnp.int32), k_cache, v_cache, jnp.int32(0)
        )
        assert k_new.shape == (
            CONFIG.n_layers, CONFIG.n_heads, b, CONFIG.head_dim,
        )
        # stale cache contents must not leak into the new block tensors
        k_cache2 = k_cache + 7.0
        _, k_new2, _ = forward_block(
            params, jnp.asarray(tokens, jnp.int32), k_cache2, v_cache, jnp.int32(0)
        )
        np.testing.assert_allclose(np.asarray(k_new), np.asarray(k_new2), rtol=0, atol=0)


class TestServingFn:
    def test_lowerable_signature(self, params):
        """make_serving_fn consumes flat params and matches forward_block."""
        fn = make_serving_fn(CONFIG, block=CONFIG.block_tokens, use_pallas=False)
        flat = params_to_list(params)
        specs = serving_arg_specs(CONFIG, CONFIG.block_tokens)
        assert len(specs[0]) == len(flat)
        rng = np.random.default_rng(3)
        tokens = jnp.asarray(rng.integers(0, 256, size=CONFIG.block_tokens), jnp.int32)
        k_cache, v_cache = _empty_cache()
        out = fn(tuple(flat), tokens, k_cache, v_cache, jnp.int32(0))
        ref = forward_block(params, tokens, k_cache, v_cache, jnp.int32(0), use_pallas=False)
        for a, b_ in zip(out, ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-6, atol=1e-6)


class TestTraining:
    def test_loss_decreases_fast(self, params):
        """A couple of SGD steps on a fixed batch should reduce the loss."""
        rng = np.random.default_rng(4)
        tokens = jnp.asarray(rng.integers(0, 256, size=(4, 33)), jnp.int32)
        grad_fn = jax.jit(jax.value_and_grad(lambda p: loss_fn(p, tokens)))
        l0, g = grad_fn(params)
        p1 = jax.tree_util.tree_map(lambda p, gg: p - 0.5 * gg, params, g)
        l1, _ = grad_fn(p1)
        assert float(l1) < float(l0)

    def test_loss_is_log_vocab_at_init(self):
        """Fresh params ≈ uniform predictions -> loss ≈ ln(256)."""
        fresh = init_params(jax.random.PRNGKey(7))
        rng = np.random.default_rng(5)
        tokens = jnp.asarray(rng.integers(0, 256, size=(2, 65)), jnp.int32)
        l = float(loss_fn(fresh, tokens))
        assert abs(l - np.log(256)) < 0.35
