"""AOT path: HLO text generation + weights serialization round-trip."""

import json
import os

import jax
import numpy as np
import pytest

from compile.aot import lower_serving
from compile.config import CONFIG
from compile.model import init_params, param_spec
from compile.train import load_weights, save_weights


class TestLowering:
    @pytest.fixture(scope="class")
    def prefill_hlo(self):
        return lower_serving(CONFIG.block_tokens)

    def test_prefill_lowers_to_text(self, prefill_hlo):
        assert prefill_hlo.startswith("HloModule")
        assert "ROOT" in prefill_hlo

    def test_root_is_3_tuple(self, prefill_hlo):
        # logits, k_new, v_new
        c = CONFIG
        want = (
            f"(f32[{c.block_tokens},{c.vocab}]"
            f"{{1,0}}, f32[{c.n_layers},{c.n_heads},{c.block_tokens},{c.head_dim}]"
        )
        assert want in prefill_hlo.replace("\n", " ")

    def test_param_count_matches_contract(self, prefill_hlo):
        # weights... + tokens + k_cache + v_cache + pos, counted from the
        # ENTRY computation signature (fused sub-computations re-declare
        # parameters, so a global count would overshoot)
        n_weights = len(param_spec(CONFIG))
        lines = prefill_hlo.splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        n_params = 0
        for line in lines[start + 1:]:
            if line.strip() == "}":
                break
            if " parameter(" in line:
                n_params += 1
        assert n_params == n_weights + 4

    def test_decode_lowers_to_text(self):
        text = lower_serving(1)
        assert "HloModule" in text
        assert f"s32[1]" in text  # single-token input


class TestWeightsRoundtrip:
    def test_save_load_identity(self, tmp_path):
        params = init_params(jax.random.PRNGKey(0))
        path = tmp_path / "w.bin"
        manifest = save_weights(params, path)
        back = load_weights(path)
        for name in params:
            np.testing.assert_array_equal(np.asarray(params[name]), np.asarray(back[name]))
        # manifest covers the file exactly, contiguously, in order
        offset = 0
        for m in manifest:
            assert m["offset_bytes"] == offset
            assert m["size_bytes"] == 4 * int(np.prod(m["shape"]))
            offset += m["size_bytes"]
        assert offset == os.path.getsize(path)

    def test_manifest_order_is_param_spec_order(self, tmp_path):
        params = init_params(jax.random.PRNGKey(1))
        manifest = save_weights(params, tmp_path / "w.bin")
        assert [m["name"] for m in manifest] == [n for n, _ in param_spec(CONFIG)]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/model_config.json")),
    reason="artifacts not built",
)
class TestBuiltArtifacts:
    """Validate the artifacts/ dir the rust runtime will consume."""

    @pytest.fixture(scope="class")
    def art(self):
        base = os.path.join(os.path.dirname(__file__), "../../artifacts")
        return base, json.load(open(os.path.join(base, "model_config.json")))

    def test_config_matches(self, art):
        _, cfg = art
        assert cfg["model"]["vocab"] == CONFIG.vocab
        assert cfg["model"]["block_tokens"] == CONFIG.block_tokens
        assert cfg["model"]["kv_block_bytes"] == CONFIG.kv_block_bytes

    def test_weights_size(self, art):
        base, cfg = art
        total = sum(m["size_bytes"] for m in cfg["weights"])
        assert os.path.getsize(os.path.join(base, "weights.bin")) == total

    def test_hlo_files_exist(self, art):
        base, cfg = art
        for f in cfg["artifacts"].values():
            p = os.path.join(base, f)
            assert os.path.getsize(p) > 10_000
            assert open(p).read(9) == "HloModule"
