"""L1 correctness: Pallas KV-cache attention kernel vs the pure-jnp oracle.

This is the CORE numeric signal for the AOT path: the kernel tested here is
the one lowered into artifacts/{prefill,decode}.hlo.txt.  hypothesis sweeps
shapes/dtypes/positions; fixed tests pin the serving configuration.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.config import CONFIG, KEY_BLOCK
from compile.kernels.attention import mha_with_cache
from compile.kernels.ref import mha_with_cache_ref


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def _check(h, b, d, s, pos, key_block, scale=1.0, seed=0):
    q = scale * _rand(seed, (h, b, d))
    k = scale * _rand(seed + 1, (h, s, d))
    v = scale * _rand(seed + 2, (h, s, d))
    out = mha_with_cache(q, k, v, jnp.int32(pos), key_block=key_block)
    ref = mha_with_cache_ref(q, k, v, jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------- fixed ---


class TestServingShapes:
    """The exact shapes the AOT artifacts use."""

    def test_prefill_shape_pos0(self):
        c = CONFIG
        _check(c.n_heads, c.block_tokens, c.head_dim, c.max_seq, 0, KEY_BLOCK)

    def test_prefill_shape_mid(self):
        c = CONFIG
        _check(c.n_heads, c.block_tokens, c.head_dim, c.max_seq, 96, KEY_BLOCK)

    def test_prefill_shape_last_block(self):
        c = CONFIG
        _check(
            c.n_heads,
            c.block_tokens,
            c.head_dim,
            c.max_seq,
            c.max_seq - c.block_tokens,
            KEY_BLOCK,
        )

    def test_decode_shape(self):
        c = CONFIG
        for pos in [0, 1, 63, 64, 200, c.max_seq - 1]:
            _check(c.n_heads, 1, c.head_dim, c.max_seq, pos, KEY_BLOCK)


class TestMasking:
    def test_garbage_beyond_pos_ignored(self):
        """Positions >= pos+B must not affect the output at all."""
        c = CONFIG
        h, b, d, s = c.n_heads, c.block_tokens, c.head_dim, c.max_seq
        pos = 64
        q = _rand(0, (h, b, d))
        k = _rand(1, (h, s, d))
        v = _rand(2, (h, s, d))
        out1 = mha_with_cache(q, k, v, jnp.int32(pos))
        # overwrite the masked region with large garbage
        k2 = k.at[:, pos + b :, :].set(1e4)
        v2 = v.at[:, pos + b :, :].set(-1e4)
        out2 = mha_with_cache(q, k2, v2, jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=0, atol=0)

    def test_causal_within_block(self):
        """Query i must not see keys at positions pos+j for j > i."""
        c = CONFIG
        h, b, d, s = 2, 8, 16, 64
        pos = 16
        q = _rand(3, (h, b, d))
        k = _rand(4, (h, s, d))
        v = _rand(5, (h, s, d))
        out1 = mha_with_cache(q, k, v, jnp.int32(pos), key_block=16)
        # change the last key/value of the block; only the last query may move
        k2 = k.at[:, pos + b - 1, :].add(3.0)
        out2 = mha_with_cache(q, k2, v, jnp.int32(pos), key_block=16)
        np.testing.assert_allclose(
            np.asarray(out1[:, :-1]), np.asarray(out2[:, :-1]), rtol=0, atol=0
        )
        assert not np.allclose(np.asarray(out1[:, -1]), np.asarray(out2[:, -1]))

    def test_single_token_pos0_attends_only_itself(self):
        h, d, s = 2, 8, 64
        q = _rand(6, (h, 1, d))
        k = _rand(7, (h, s, d))
        v = _rand(8, (h, s, d))
        out = mha_with_cache(q, k, v, jnp.int32(0), key_block=16)
        # softmax over a single valid key -> output == v[:, 0]
        np.testing.assert_allclose(
            np.asarray(out[:, 0]), np.asarray(v[:, 0]), rtol=1e-6, atol=1e-6
        )


class TestNumerics:
    def test_large_scores_stable(self):
        _check(2, 4, 8, 32, 5, 16, scale=10.0)

    def test_extreme_scores_finite(self):
        # at scale 30 the softmax saturates: outputs must stay finite and
        # close to the oracle up to saturation-level tolerance
        q = 30.0 * _rand(0, (2, 4, 8))
        k = 30.0 * _rand(1, (2, 32, 8))
        v = 30.0 * _rand(2, (2, 32, 8))
        out = mha_with_cache(q, k, v, jnp.int32(5), key_block=16)
        ref = mha_with_cache_ref(q, k, v, jnp.int32(5))
        assert np.isfinite(np.asarray(out)).all()
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-3, atol=5e-2)

    def test_tiny_scores_stable(self):
        _check(2, 4, 8, 32, 5, 16, scale=1e-4)

    def test_s_not_multiple_of_key_block_raises(self):
        q = _rand(0, (1, 2, 4))
        k = _rand(1, (1, 33, 4))
        v = _rand(2, (1, 33, 4))
        with pytest.raises(ValueError):
            mha_with_cache(q, k, v, jnp.int32(0), key_block=16)


# ------------------------------------------------------------ hypothesis ---


@settings(max_examples=30, deadline=None)
@given(
    h=st.integers(1, 4),
    b=st.sampled_from([1, 2, 4, 8, 16, 32]),
    d=st.sampled_from([4, 8, 16, 32]),
    s_blocks=st.integers(1, 4),
    key_block=st.sampled_from([8, 16, 32]),
    pos_frac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_ref_sweep(h, b, d, s_blocks, key_block, pos_frac, seed):
    s = s_blocks * key_block
    b = min(b, s)  # block cannot exceed cache
    pos = int(pos_frac * max(0, s - b))
    _check(h, b, d, s, pos, key_block, seed=seed)


@settings(max_examples=10, deadline=None)
@given(
    pos=st.integers(0, CONFIG.max_seq - CONFIG.block_tokens),
    seed=st.integers(0, 2**16),
)
def test_kernel_serving_config_positions(pos, seed):
    c = CONFIG
    _check(c.n_heads, c.block_tokens, c.head_dim, c.max_seq, pos, KEY_BLOCK, seed=seed)
