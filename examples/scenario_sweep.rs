//! Run every built-in scenario — the paper's 19x5 testbed, the
//! Starlink-like 72x22 mega-shell, the Kuiper-like 34x34 shell, the
//! mega-shell stress shape, and the federated dual- and tri-shell runs —
//! twice each, verify the metrics JSON is byte-identical across the two
//! runs (the determinism contract), and print the reports.
//!
//! ```text
//! cargo run --release --example scenario_sweep
//! ```

use skymemory::sim::harness::{run_federated_scenario, run_scenario};
use skymemory::sim::scenario::{FederatedScenarioSpec, ScenarioSpec};

fn main() {
    let seed = match std::env::args().nth(1).and_then(|a| a.parse().ok()) {
        Some(s) => s,
        None => 42u64,
    };
    println!("# scenario sweep, seed {seed}");
    let mut all_deterministic = true;
    for spec in ScenarioSpec::builtin(seed) {
        let t0 = std::time::Instant::now();
        let first = run_scenario(&spec).to_json_string();
        let second = run_scenario(&spec).to_json_string();
        let deterministic = first == second;
        all_deterministic &= deterministic;
        println!("{first}");
        println!(
            "# {}: {} sats, {} epochs, {} requests, hit-rate in JSON above; \
             deterministic across two runs: {} ({:.2?} for both runs)",
            spec.name,
            spec.torus().len(),
            spec.epochs,
            spec.total_requests(),
            deterministic,
            t0.elapsed()
        );
        assert!(deterministic, "{}: metrics JSON differed between runs", spec.name);
    }
    // the federated scenarios hold the same contract
    for fed in [
        FederatedScenarioSpec::federated_dual_shell(seed),
        FederatedScenarioSpec::federated_tri_shell(seed),
    ] {
        let t0 = std::time::Instant::now();
        let first = run_federated_scenario(&fed).to_json_string();
        let second = run_federated_scenario(&fed).to_json_string();
        let deterministic = first == second;
        all_deterministic &= deterministic;
        println!("{first}");
        println!(
            "# {}: {} shells ({} sats total), {} epochs, {} requests; \
             deterministic across two runs: {} ({:.2?} for both runs)",
            fed.name,
            fed.shells.len(),
            fed.shells.iter().map(|s| s.torus().len()).sum::<usize>(),
            fed.epochs,
            fed.total_requests(),
            deterministic,
            t0.elapsed()
        );
        assert!(deterministic, "{}: metrics JSON differed between runs", fed.name);
    }
    assert!(all_deterministic);
    println!("# all scenarios deterministic: same seed -> identical metrics JSON");
}
