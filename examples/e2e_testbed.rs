//! End-to-end testbed reproduction (paper §5, Table 3) — THE e2e driver.
//!
//! The paper: a 19x5 cFS constellation on 5 NUCs, a Jetson-hosted
//! TinyLlama, a 250-character prompt → 4 x 128-token blocks (~2.9 MB each,
//! 8-bit quantized), striped as 6 kB chunks over 10 LOS satellites; a
//! 30-token generation speeds up from 6.2 s to 4.9 s (21%) with
//! Optimum-Quanto, 10.2 s → 7.8 s (24%) with HQQ.
//!
//! Here: the same 19x5 constellation (in-process, with wall-clock link
//! latency emulation), the build-time-trained byte LM, a 250-character
//! prompt → 7 x 32-token blocks, 6 kB chunks over 10 servers, 30 new
//! tokens.  We report the same table — generation seconds without / with
//! the KVC for both quantizers — plus a batched serving run (latency /
//! throughput), and write results/table3.csv + results/e2e_serving.csv.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_testbed
//! ```

use skymemory::constellation::geometry::Geometry;
use skymemory::coordinator::{GenRequest, Stack, StackConfig};
use skymemory::kvc::quantize::Quantizer;
use skymemory::net::transport::LinkModel;
use skymemory::sim::workload::{generate as gen_workload, WorkloadConfig};
use skymemory::util::bench::summarize;
use std::fmt::Write as _;
use std::time::{Duration, Instant};

/// The paper's ~250-character validation prompt, adapted thematically and
/// trimmed to 224 bytes = 7 exact 32-token blocks (our context is 256).
const PROMPT: &str = "We expand the scope of cache memory to include LEO constellations, \
highly distributed systems with thousands of satellites connected with free-space \
optics inter-satellite links, always one hop from any point on earth.";

/// Link-latency calibration.  The Table 3 speedups depend on the
/// fetch-to-prefill time ratio, not on absolute seconds.  From the paper's
/// own numbers: 4 blocks save 6.2-4.9 = 1.3 s, i.e. ~325 ms of Jetson
/// prefill replaced by a fetch of roughly 60-80 ms per 2.9 MB block —
/// fetch/prefill ~ 0.2.  Our byte-LM prefills a block in ~3 ms, so the
/// emulated constellation must answer in ~0.6 ms per block to present the
/// same ratio; full-scale LEO RTTs (~5-100 ms at this 19x5 geometry) are
/// scaled down accordingly (see DESIGN.md §Hardware-Adaptation).
const LINK_SCALE: f64 = 1.0 / 300.0;

fn build_stack(quantizer: Quantizer, link_scale: f64) -> anyhow::Result<Stack> {
    let mut cfg = StackConfig::default(); // 19x5, the paper's constellation
    cfg.kvc.quantizer = quantizer;
    cfg.kvc.n_servers = 10; // paper: "10 LOS cFS satellites to stripe across"
    cfg.kvc.chunk_size = 6000; // paper: 6 kB chunks
    let mut link = LinkModel::laser_defaults(Geometry::new(550.0, 19, 5));
    link.sleep_scale = link_scale;
    link.bandwidth_bps = 200e6;
    cfg.link = Some(link);
    cfg.n_workers = 1;
    Stack::build(cfg)
}

fn timed_generation(stack: &Stack, use_cache: bool, warm: bool) -> anyhow::Result<f64> {
    let req = GenRequest {
        prompt: PROMPT.into(),
        max_new_tokens: 30, // paper: 30-token generation
        use_cache,
        ..Default::default()
    };
    // untimed warm-up: spins up PJRT/thread pools; when `warm`, it also
    // primes the constellation with the prompt's blocks
    let mut prime = req.clone();
    prime.use_cache = warm && use_cache;
    stack.router.generate(prime)?;
    // median of 5 timed runs
    let mut times = Vec::new();
    for _ in 0..5 {
        let r = stack.router.generate(req.clone())?;
        times.push(r.total_s);
    }
    times.sort_by(f64::total_cmp);
    Ok(times[2])
}

fn table3(outdir: &std::path::Path) -> anyhow::Result<()> {
    println!("=== Table 3: Jetson cFS testbed experiment (scaled) ===");
    println!("{:<16} {:>14} {:>12} {:>9}", "quantization", "no KVC (s)", "KVC (s)", "speedup");
    let mut csv = String::from("quantization,no_kvc_s,kvc_s,speedup_pct\n");
    for (name, q) in [
        ("optimum-quanto", Quantizer::QuantoInt8 { group: 32 }),
        ("hqq", Quantizer::HqqInt8 { group: 32 }),
    ] {
        let stack = build_stack(q, LINK_SCALE)?;
        let cold = timed_generation(&stack, false, false)?;
        let warm = timed_generation(&stack, true, true)?;
        let speedup = 100.0 * (1.0 - warm / cold);
        println!("{name:<16} {cold:>14.3} {warm:>12.3} {speedup:>8.1}%");
        let _ = writeln!(csv, "{name},{cold:.4},{warm:.4},{speedup:.1}");
    }
    println!("(paper: quanto 6.2 -> 4.9 s = 21%; hqq 10.2 -> 7.8 s = 24%)");
    println!("(absolute seconds differ — the Jetson's quantized-model compute is ~80x ours;");
    println!(" the KVC-vs-no-KVC *speedup* is the comparable quantity)");
    std::fs::write(outdir.join("table3.csv"), csv)?;
    Ok(())
}

fn serving_run(outdir: &std::path::Path) -> anyhow::Result<()> {
    println!("\n=== batched serving over the constellation cache ===");
    let stack = build_stack(Quantizer::QuantoInt8 { group: 32 }, LINK_SCALE)?;
    let wl =
        WorkloadConfig { n_contexts: 4, context_chars: 160, n_questions: 6, seed: 42, ..Default::default() };
    let items = gen_workload(&wl, 32);
    let t0 = Instant::now();
    // submit everything (router fans across workers), then collect
    let rxs: Vec<_> = items
        .iter()
        .map(|it| {
            stack.router.submit(GenRequest {
                prompt: it.prompt.clone(),
                max_new_tokens: 16,
                ..Default::default()
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut tokens = 0usize;
    let mut cached_blocks = 0usize;
    for rx in rxs {
        let r = rx.recv()??;
        latencies.push(Duration::from_secs_f64(r.total_s));
        tokens += r.tokens.len();
        cached_blocks += r.cached_blocks;
    }
    let wall = t0.elapsed();
    let summary = summarize("serving e2e latency", latencies);
    println!("{}", summary.report());
    println!(
        "32 requests in {:.2}s -> {:.2} req/s, {:.1} tok/s, {} blocks served from orbit, hit rate {:.0}%",
        wall.as_secs_f64(),
        32.0 / wall.as_secs_f64(),
        tokens as f64 / wall.as_secs_f64(),
        cached_blocks,
        stack.metrics.block_hit_rate() * 100.0
    );
    let csv = format!(
        "requests,wall_s,req_per_s,tok_per_s,mean_latency_s,p95_latency_s,cached_blocks,hit_rate\n32,{:.3},{:.3},{:.3},{:.4},{:.4},{},{:.3}\n",
        wall.as_secs_f64(),
        32.0 / wall.as_secs_f64(),
        tokens as f64 / wall.as_secs_f64(),
        summary.mean.as_secs_f64(),
        summary.p95.as_secs_f64(),
        cached_blocks,
        stack.metrics.block_hit_rate()
    );
    std::fs::write(outdir.join("e2e_serving.csv"), csv)?;
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let outdir = std::path::PathBuf::from(
        std::env::args()
            .skip_while(|a| a != "--outdir")
            .nth(1)
            .unwrap_or_else(|| "results".into()),
    );
    std::fs::create_dir_all(&outdir)?;
    table3(&outdir)?;
    serving_run(&outdir)?;
    println!("\nwrote {}/table3.csv and {}/e2e_serving.csv", outdir.display(), outdir.display());
    Ok(())
}
