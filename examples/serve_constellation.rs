//! Full networked demo: a 19x5 **UDP** constellation (real sockets, CCSDS
//! Space Packets, greedy ISL forwarding — the paper's 5-NUC testbed with
//! threads standing in for the NUCs), the KVC manager speaking to it over
//! the UDP transport, an HTTP serving front-end, and a batched client.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_constellation
//! ```

use skymemory::constellation::los::LosGrid;
use skymemory::constellation::topology::{SatId, Torus};
use skymemory::coordinator::http::{client, HttpServer};
use skymemory::coordinator::{Executor, Metrics, Router};
use skymemory::kvc::block::model_fingerprint;
use skymemory::kvc::eviction::EvictionPolicy;
use skymemory::kvc::manager::{KvcConfig, KvcManager};
use skymemory::net::transport::{GroundView, Transport};
use skymemory::net::udp::{UdpFleet, UdpTransport};
use skymemory::runtime::model_config::{default_artifacts_dir, Artifacts};
use skymemory::sim::workload::{generate as gen_workload, WorkloadConfig};
use skymemory::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let torus = Torus::new(5, 19);
    println!("spawning 19x5 UDP constellation (95 satellites, CCSDS SPP)...");
    let fleet = UdpFleet::spawn(torus, 64 << 20, EvictionPolicy::Gossip, None)?;

    let center = SatId::new(2, 9);
    let ground = GroundView::new(center, &LosGrid::new(center, 2, 2), torus.sats_per_plane);
    let transport: Arc<dyn Transport> = Arc::new(UdpTransport::new(
        torus,
        fleet.book.clone(),
        ground,
        Duration::from_secs(5),
    )?);
    let kvc = KvcConfig { n_servers: 10, ..KvcConfig::default() };
    let manager = Arc::new(KvcManager::new(kvc, torus, transport));

    println!("loading AOT model + spawning serving stack...");
    let artifacts = Artifacts::load(default_artifacts_dir())?;
    let fingerprint = model_fingerprint("skymemory-bytelm", "byte-v1", &artifacts.weights_digest()?);
    let executor = Executor::spawn(artifacts, 8)?;
    let metrics = Arc::new(Metrics::default());
    let router = Arc::new(Router::spawn(executor, Some(manager.clone()), fingerprint, 2, metrics.clone()));
    let server = HttpServer::spawn("127.0.0.1:0", router.clone())?;
    println!("serving on http://{}", server.addr);

    // batched client load over HTTP
    let wl =
        WorkloadConfig { n_contexts: 3, context_chars: 130, n_questions: 5, seed: 11, ..Default::default() };
    let items = gen_workload(&wl, 24);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for chunk in items.chunks(6) {
        let addr = server.addr;
        let chunk: Vec<String> = chunk.iter().map(|i| i.prompt.clone()).collect();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<f64>> {
            let mut lat = Vec::new();
            for prompt in chunk {
                let body = skymemory::util::json::obj(vec![
                    ("prompt", skymemory::util::json::s(&prompt)),
                    ("max_tokens", skymemory::util::json::n(12.0)),
                ])
                .to_string();
                let (status, resp) = client::post(addr, "/generate", &body)?;
                anyhow::ensure!(status == 200, "status {status}: {resp}");
                let j = Json::parse(&resp)?;
                lat.push(j.get("total_s").and_then(Json::as_f64).unwrap_or(0.0));
            }
            Ok(lat)
        }));
    }
    let mut latencies = Vec::new();
    for h in handles {
        latencies.extend(h.join().unwrap()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(f64::total_cmp);
    println!(
        "\n24 HTTP requests in {wall:.2}s ({:.1} req/s); latency p50 {:.0} ms p95 {:.0} ms",
        24.0 / wall,
        latencies[latencies.len() / 2] * 1e3,
        latencies[latencies.len() * 95 / 100] * 1e3,
    );

    let (_, metrics_text) = client::get(server.addr, "/metrics")?;
    for line in metrics_text.lines().filter(|l| {
        l.starts_with("skymemory_cache") || l.starts_with("skymemory_block_hit")
    }) {
        println!("  {line}");
    }
    println!("constellation stores {} chunks across 95 UDP satellites", fleet.total_chunks());

    server.shutdown();
    fleet.shutdown();
    Ok(())
}
