//! Rotation & migration demo (paper §3.4, Figures 5/8/9/10): store a
//! prompt's KVC, advance the constellation several rotation epochs with
//! column migrations, and show the cache still hits — then skip migration
//! for a hop-aware layout and show how drift degrades it.
//!
//! ```bash
//! make artifacts && cargo run --release --example migration_demo
//! ```

use skymemory::coordinator::{GenRequest, Stack, StackConfig};
use skymemory::mapping::migration::{by_plane, migration_plan};
use skymemory::mapping::Strategy;

fn main() -> anyhow::Result<()> {
    let stack = Stack::build(StackConfig::default())?;
    let prompt = "Satellites do not wait. Every few minutes a column of the grid \
                  slides over the horizon and a new column rises in the west.";
    let req = GenRequest { prompt: prompt.into(), max_new_tokens: 24, ..Default::default() };

    println!("epoch 0: first generation (cold) ...");
    let cold = stack.router.generate(req.clone())?;
    println!(
        "  total {:.1} ms, cached {} prefilled {}",
        cold.total_s * 1e3,
        cold.cached_blocks,
        cold.prefill_blocks
    );

    for epoch in 0..3u64 {
        // show the migration plan the manager derives for this epoch
        let torus = stack.fleet.torus;
        let center = stack.manager.transport().closest();
        let plan = migration_plan(
            &torus,
            Strategy::RotationHopAware,
            center,
            stack.manager.config.n_servers,
            0,
        );
        println!(
            "\nepoch {} -> {}: migrating {} servers in {} parallel planes (east column -> entering west column)",
            epoch,
            epoch + 1,
            plan.len(),
            by_plane(&plan).len()
        );
        let moved = stack.manager.advance_epoch(epoch)?;
        println!("  {moved} chunks handed over");

        let warm = stack.router.generate(req.clone())?;
        println!(
            "  post-migration generation: total {:.1} ms, cached {} prefilled {} (cache must still hit)",
            warm.total_s * 1e3,
            warm.cached_blocks,
            warm.prefill_blocks
        );
        assert!(warm.cached_blocks > 0, "migration lost the cache!");
    }

    println!(
        "\nafter 3 epochs: {} chunks in orbit, hit rate {:.0}%",
        stack.fleet.total_chunks(),
        stack.metrics.block_hit_rate() * 100.0
    );
    println!("(hop-aware layouts skip migration and pay growing hop counts instead — see fig16 bench)");
    Ok(())
}
