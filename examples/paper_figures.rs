//! Regenerate every table and figure of the paper's evaluation (except the
//! model-driven Table 3, which `e2e_testbed` produces) into results/.
//!
//! ```bash
//! cargo run --release --example paper_figures -- --all --outdir results
//! ```

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let outdir = std::path::PathBuf::from(
        args.iter()
            .position(|a| a == "--outdir")
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| "results".into()),
    );
    let files = skymemory::repro::write_all(&outdir)?;
    for f in &files {
        println!("wrote {}", f.display());
    }
    println!("\n--- Figure 13 (rotation-aware) 5x5 ---");
    print!("{}", section(&skymemory::repro::fig13(), "5x5"));
    println!("--- Figure 14 (hop-aware) 5x5 ---");
    print!("{}", section(&skymemory::repro::fig14(), "5x5"));
    println!("--- Figure 15 (rotation-and-hop-aware) 5x5 ---");
    print!("{}", section(&skymemory::repro::fig15(), "5x5"));
    println!("--- Figure 16 headline ---");
    print!("{}", skymemory::repro::fig16_summary());
    Ok(())
}

fn section(full: &str, which: &str) -> String {
    let mut out = String::new();
    let mut in_section = false;
    for line in full.lines() {
        if line.starts_with('#') {
            in_section = line.contains(which);
            continue;
        }
        if in_section {
            out.push_str(line);
            out.push('\n');
        }
    }
    out
}
