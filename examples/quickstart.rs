//! Quickstart: stand up the full in-process SkyMemory stack (PJRT model +
//! constellation + KVC manager + router), run the same prompt twice, and
//! watch the second request restore its prefix from the satellites.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use skymemory::coordinator::{GenRequest, Stack, StackConfig};

fn main() -> anyhow::Result<()> {
    println!("building the SkyMemory stack (19x5 constellation, rot+hop mapping)...");
    let stack = Stack::build(StackConfig::default())?;

    let prompt = "The satellite passes overhead every ninety minutes, and the \
                  cache moves with it. A constellation in low earth orbit is a \
                  ring of memory that the planet spins beneath:";
    let req = GenRequest { prompt: prompt.into(), max_new_tokens: 48, ..Default::default() };

    println!("\nprompt ({} chars): {prompt:?}\n", prompt.len());
    for run in 1..=3 {
        let r = stack.router.generate(req.clone())?;
        println!(
            "run {run}: ttft {:6.1} ms | total {:6.1} ms | blocks cached {} / prefilled {} | kvc fetch {:.1} ms store {:.1} ms",
            r.ttft_s * 1e3,
            r.total_s * 1e3,
            r.cached_blocks,
            r.prefill_blocks,
            r.kvc_fetch_s * 1e3,
            r.kvc_store_s * 1e3,
        );
        if run == 1 {
            println!("  generated: {:?}", r.text);
        }
    }

    println!("\nconstellation now stores {} chunks across {} satellites",
        stack.fleet.total_chunks(),
        stack.fleet.torus.len());
    println!("cache hit rate (blocks): {:.0}%", stack.metrics.block_hit_rate() * 100.0);
    Ok(())
}
