//! Micro-timer for the PJRT prefill/decode steps (the L2 hot path) —
//! used by the §Perf iteration loop in EXPERIMENTS.md.

fn main() {
    let ex = skymemory::coordinator::Executor::spawn_default(1).unwrap();
    let slot = ex.alloc_slot().unwrap();
    let b = ex.dims.block_tokens;
    let tokens: Vec<i32> = (0..b as i32).collect();
    ex.prefill(slot, tokens, 0).unwrap();
    for i in 0..20usize {
        ex.decode(slot, 65, b + i).unwrap();
    }
    let t0 = std::time::Instant::now();
    let n = 100u32;
    for i in 0..n as usize {
        ex.decode(slot, 65, b + 20 + (i % 100)).unwrap();
    }
    println!("decode step mean: {:?}", t0.elapsed() / n);
    let t0 = std::time::Instant::now();
    for _ in 0..20 {
        ex.prefill(slot, (0..b as i32).collect(), 0).unwrap();
    }
    println!("prefill step mean: {:?}", t0.elapsed() / 20);
}
