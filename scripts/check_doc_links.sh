#!/usr/bin/env bash
# Doc link check: every repo path referenced by ARCHITECTURE.md or
# docs/*.md (tokens starting with rust/, docs/, examples/, scripts/ or
# .github/) must exist. Keeps the documentation pass honest; runs in CI
# (.github/workflows/ci.yml). Exits nonzero listing every dangling
# reference.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
for doc in ARCHITECTURE.md docs/*.md; do
    [ -f "$doc" ] || { echo "missing doc file: $doc"; fail=1; continue; }
    # path-like tokens; trailing sentence punctuation stripped below
    refs=$(grep -oE '(rust|docs|examples|scripts|\.github)/[A-Za-z0-9_./-]+' "$doc" | sort -u)
    for ref in $refs; do
        # strip trailing dots (end of sentence) but keep extensions
        while [ "${ref%.}" != "$ref" ]; do ref="${ref%.}"; done
        if [ ! -e "$ref" ]; then
            echo "$doc: dangling reference: $ref"
            fail=1
        fi
    done
done

if [ "$fail" -ne 0 ]; then
    echo "doc link check FAILED"
    exit 1
fi
echo "doc link check OK"
