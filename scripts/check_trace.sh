#!/usr/bin/env bash
# Chrome-trace validity check: the file passed as $1 must be valid
# trace-event JSON (the format docs/TRACING.md documents and Perfetto /
# chrome://tracing load): a traceEvents array, non-empty, every event
# carrying name/ph/ts/pid/tid, every "X" (complete-span) event carrying
# dur, instants marked with a scope. Runs in CI
# (.github/workflows/ci.yml) against `skymemory trace --format chrome`.
set -euo pipefail

if [ $# -ne 1 ] || [ ! -f "$1" ]; then
    echo "usage: $0 <trace.json>" >&2
    exit 2
fi

python3 - "$1" <<'EOF'
import json
import sys

path = sys.argv[1]
with open(path) as f:
    doc = json.load(f)

events = doc.get("traceEvents")
assert isinstance(events, list), "traceEvents must be an array"
assert events, "traceEvents must not be empty"

phases = {}
for i, ev in enumerate(events):
    for key in ("name", "ph", "ts", "pid", "tid"):
        assert key in ev, f"event {i} missing {key!r}: {ev}"
    ph = ev["ph"]
    phases[ph] = phases.get(ph, 0) + 1
    if ph == "X":
        assert "dur" in ev, f"X event {i} missing dur: {ev}"
        assert ev["dur"] >= 0, f"X event {i} has negative dur: {ev}"
    if ph == "i":
        assert ev.get("s") in ("t", "p", "g"), f"instant {i} missing scope: {ev}"

assert phases.get("M", 0) > 0, "no metadata (process/thread name) events"
spans = phases.get("X", 0) + phases.get("i", 0)
assert spans > 0, "no span or instant events"
print(f"{path}: OK — {len(events)} events ({phases})")
EOF
